//! Discrete-event TetriInfer cluster: the paper's full pipeline —
//!
//!   arrival → global scheduler (least-load prefill routing, §3.2)
//!           → prefill local scheduler (FCFS/SJF/LJF, §3.3.1)
//!           → length predictor (parallel/sequential, §3.3.2)
//!           → chunked prefill (fixed ChunkSize iterations, §3.3.3)
//!           → dispatcher (power-of-two over broadcast loads, §3.3.4)
//!           → KV transfer over the emulated fabric (Figure 9)
//!           → decode local scheduler (greedy/reserve-*, §3.4)
//!           → continuous-batching decode until completion
//!
//! plus the cluster monitor's periodic load broadcast and instance
//! flipping (§3.5). Deterministic given (config, trace).
//!
//! Hot-path layout (see DESIGN.md §Hot paths): the request book is a
//! dense arena `Vec<ReqState>` — at `run()` the trace is renumbered so
//! every event carries an arena *slot*, and every per-event lookup is a
//! direct index (no hashing, no `Request` clones). Per-instance load is
//! read from O(1) cached counters, the least-loaded prefill choice is
//! served from a dirty-tracked cache, and the monitor tick reuses its
//! `broadcast`/`since_tick` buffers instead of reallocating them.

use crate::api::{NullObserver, Observer};
use crate::decode::{DecodeJob, DecodeScheduler};
use crate::fabric::Fabric;
use crate::kvcache::PagedKvCache;
use crate::metrics::RunMetrics;
use crate::predictor::{OraclePredictor, Predictor};
use crate::prefill::{choose, Chunk, Chunker, DecodeLoad, PrefillScheduler};
use crate::sim::{Event, EventQueue};
use crate::types::{ReqId, ReqMeta, Request, RequestRecord, Role, Us};
use crate::util::Pcg;

use super::config::{ClusterConfig, PredictorMode};

/// Predictions a single saturated chunk iteration can absorb in parallel
/// mode (the predict model is ~10x faster than the target, §3.3.2).
const PREDICTIONS_PER_CHUNK: u32 = 10;
/// Main-LLM slowdown while co-running the predictor (Figure 17: ~10%).
const PARALLEL_PREDICT_OVERHEAD: f64 = 0.10;

/// Sentinel for "first token not yet produced".
const NO_TIME: Us = Us::MAX;

/// Arena entry: one request plus the driver-side state that used to live
/// in side HashMaps (first-token time) or nowhere at all (the prefilling
/// instance, which the KV-release path needs — see
/// `release_prefill_resident`).
struct ReqState {
    req: Request,
    first_token: Us,
    /// The prefill instance (and its flip epoch) holding this request's
    /// prompt KV until the transfer out completes. Consumed (`take`n)
    /// exactly once; the epoch guards against the instance flipping away
    /// and back while the KV is in flight (a reborn incarnation must not
    /// have a stale release land on its counter).
    prefilled_by: Option<(usize, u32)>,
    /// The arrival event fired at least once (mid-flip retries re-enqueue
    /// `Event::Arrival`; observers must see one arrival per request).
    seen: bool,
}

struct PrefillInst {
    sched: PrefillScheduler,
    chunker: Chunker,
    busy: bool,
    /// Chunk currently executing (applied at PrefillIterDone).
    current: Option<Chunk>,
    /// KV tokens resident for prefilled-but-untransferred requests plus
    /// in-flight chunked requests (backpressure input).
    resident_kv: u64,
    /// Predictions waiting to ride the accelerator (parallel mode).
    pending_pred: u32,
    last_active: Us,
}

impl PrefillInst {
    /// Scheduling load (§3.2): queued + in-flight prompt tokens. O(1) —
    /// both counters are maintained incrementally.
    fn load(&self) -> u64 {
        self.sched.queued_tokens() + self.chunker.pending_tokens()
    }
}

struct DecodeInst {
    sched: DecodeScheduler,
    kv: PagedKvCache,
    busy: bool,
    /// Completions computed at iteration start, recorded at iteration end
    /// (buffer reused across iterations).
    pending_done: Vec<ReqId>,
    last_active: Us,
}

enum InstState {
    Prefill(PrefillInst),
    Decode(DecodeInst),
    Flipping { to: Role },
}

pub struct Cluster {
    pub cfg: ClusterConfig,
    queue: EventQueue,
    insts: Vec<InstState>,
    /// Request arena: everything the global scheduler has seen, indexed by
    /// arena slot (events carry slots, not original request ids).
    requests: Vec<ReqState>,
    /// Last monitor broadcast of decode loads (stale by design, §3.2).
    /// Buffer reused across ticks.
    broadcast: Vec<DecodeLoad>,
    /// What this coordinator's dispatchers sent since the last broadcast:
    /// (heavy, light, kv footprint) per instance. A real dispatcher knows
    /// its own recent sends even though the broadcast is stale.
    since_tick: Vec<(u32, u32, u64)>,
    /// Scratch buffer for merged load views (avoids an allocation per
    /// dispatch on the hot path — see EXPERIMENTS.md §Perf).
    loads_scratch: Vec<DecodeLoad>,
    /// Cached least-loaded prefill instance (the §3.2 routing target).
    /// Invalidated when the cached instance's load grows or the instance
    /// set changes; kept fresh in O(1) when any other instance's load
    /// drops below it.
    least_prefill: Option<usize>,
    least_prefill_dirty: bool,
    /// Per-instance flip epoch: bumped when an instance leaves its role
    /// (any in-flight references to the old incarnation become stale).
    insts_epoch: Vec<u32>,
    predictor: OraclePredictor,
    fabric: Fabric,
    rng: Pcg,
    pub metrics: RunMetrics,
    /// Prefilled requests awaiting a dispatch target (mid-flip windows).
    pending_dispatch: Vec<ReqId>,
    /// Requests remaining (termination condition).
    outstanding: usize,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        let mut insts = Vec::new();
        for _ in 0..cfg.n_prefill {
            insts.push(InstState::Prefill(new_prefill_inst(&cfg, 0)));
        }
        for _ in 0..cfg.n_decode {
            insts.push(InstState::Decode(new_decode_inst(&cfg)));
        }
        let n = insts.len();
        let predictor = OraclePredictor::new(
            cfg.granularity,
            cfg.n_buckets,
            if cfg.predictor_mode == PredictorMode::Disabled { 0.0 } else { cfg.predictor_accuracy },
            cfg.seed ^ 0xabcd,
        );
        let mut fabric = Fabric::new(cfg.link, cfg.cost.kv_bytes_per_tok);
        fabric.granularity = cfg.transfer_granularity;
        let rng = Pcg::with_stream(cfg.seed, 0x1234_5678_9abc_def1);
        Cluster {
            cfg,
            queue: EventQueue::new(),
            insts,
            requests: Vec::new(),
            broadcast: Vec::new(),
            since_tick: vec![(0, 0, 0); n],
            loads_scratch: Vec::with_capacity(n),
            least_prefill: None,
            least_prefill_dirty: true,
            insts_epoch: vec![0; n],
            predictor,
            fabric,
            rng,
            metrics: RunMetrics {
                busy_us: vec![0; n],
                alive_us: vec![0; n],
                decode_assign: vec![(0, 0); n],
                ..Default::default()
            },
            pending_dispatch: Vec::new(),
            outstanding: 0,
        }
    }

    /// Run a trace to completion; returns final metrics.
    pub fn run(self, trace: Vec<Request>) -> RunMetrics {
        self.run_observed(trace, &mut NullObserver)
    }

    /// Run a trace to completion, streaming per-event hooks to `obs`.
    /// The observer never influences the run: metrics are bit-identical
    /// to `run` (golden-tested through `api::Scenario`).
    pub fn run_observed(mut self, trace: Vec<Request>, obs: &mut dyn Observer) -> RunMetrics {
        self.outstanding = trace.len();
        // Renumber the trace into dense arena slots: all internal ids
        // (events, KV tables, queues) are slots from here on; the original
        // request id resurfaces only in the final RequestRecord.
        self.requests = trace
            .into_iter()
            .map(|req| ReqState { req, first_token: NO_TIME, prefilled_by: None, seen: false })
            .collect();
        for slot in 0..self.requests.len() {
            self.queue
                .schedule_at(self.requests[slot].req.arrival, Event::Arrival(slot as ReqId));
        }
        self.refresh_broadcast();
        self.queue.schedule_in(self.cfg.monitor_interval_us, Event::MonitorTick);

        while self.outstanding > 0 {
            let Some((_, ev)) = self.queue.pop() else {
                panic!(
                    "cluster deadlock: {} requests outstanding, no events",
                    self.outstanding
                );
            };
            self.metrics.events += 1;
            self.handle(ev, obs);
        }
        let now = self.queue.now();
        self.metrics.makespan_us = now;
        for a in self.metrics.alive_us.iter_mut() {
            *a = now;
        }
        for inst in &self.insts {
            if let InstState::Decode(d) = inst {
                self.metrics.swapped_tokens += d.kv.swapped_out_tokens;
            }
        }
        self.metrics
    }

    fn handle(&mut self, ev: Event, obs: &mut dyn Observer) {
        match ev {
            Event::Arrival(slot) => self.on_arrival(slot, obs),
            Event::PredictDone { instance, req } => self.on_predict_done(instance, req, obs),
            Event::PrefillIterDone { instance } => self.on_prefill_done(instance, obs),
            Event::TransferDone { instance, req } => self.on_transfer_done(instance, req, obs),
            Event::DecodeIterDone { instance } => self.on_decode_done(instance, obs),
            Event::MonitorTick => self.on_monitor_tick(obs),
            Event::FlipDone { instance } => self.on_flip_done(instance),
            Event::CoupledIterDone { .. } => unreachable!("coupled events belong to the baseline"),
        }
    }

    /// Scheduler-facing view of an arena slot (slot becomes the id).
    fn meta_of(&self, slot: ReqId) -> ReqMeta {
        let r = &self.requests[slot as usize].req;
        ReqMeta {
            id: slot,
            task: r.task,
            arrival: r.arrival,
            prompt_len: r.prompt_len,
            predicted: r.predicted,
        }
    }

    // --------------------------------------------- least-loaded prefill

    /// The cached instance's load grew (a request was routed to it): the
    /// cache may no longer be the minimum.
    fn note_prefill_load_increased(&mut self, i: usize) {
        if self.least_prefill == Some(i) {
            self.least_prefill_dirty = true;
        }
    }

    /// Instance `i`'s load shrank (a chunk was sliced off): it may now
    /// undercut the cached minimum. Same tie-break as the full scan
    /// (lowest index among minima), so cache hits and rescans agree.
    fn note_prefill_load_decreased(&mut self, i: usize) {
        if self.least_prefill_dirty {
            return;
        }
        let Some(j) = self.least_prefill else {
            self.least_prefill_dirty = true;
            return;
        };
        if i == j {
            return; // the minimum got smaller: still the minimum
        }
        let (InstState::Prefill(pi), InstState::Prefill(pj)) = (&self.insts[i], &self.insts[j])
        else {
            self.least_prefill_dirty = true;
            return;
        };
        let (li, lj) = (pi.load(), pj.load());
        if li < lj || (li == lj && i < j) {
            self.least_prefill = Some(i);
        }
    }

    /// Least-loaded prefill instance (§3.2 "choose a prefill instance with
    /// the least load"). Serves from the cache when clean; otherwise one
    /// O(n_instances) pass over the O(1) load counters.
    fn pick_prefill(&mut self) -> Option<usize> {
        if !self.least_prefill_dirty {
            if let Some(i) = self.least_prefill {
                if matches!(self.insts[i], InstState::Prefill(_)) {
                    return Some(i);
                }
            }
        }
        let mut best: Option<(usize, u64)> = None;
        for (i, s) in self.insts.iter().enumerate() {
            if let InstState::Prefill(p) = s {
                let load = p.load();
                if best.map(|(_, bl)| load < bl).unwrap_or(true) {
                    best = Some((i, load));
                }
            }
        }
        self.least_prefill = best.map(|(i, _)| i);
        self.least_prefill_dirty = false;
        self.least_prefill
    }

    // ----------------------------------------------------------- arrival

    fn on_arrival(&mut self, slot: ReqId, obs: &mut dyn Observer) {
        if !self.requests[slot as usize].seen {
            self.requests[slot as usize].seen = true;
            let req = self.requests[slot as usize].req;
            obs.on_arrival(self.queue.now(), &req);
        }
        let Some(i) = self.pick_prefill() else {
            // No prefill instance right now (all flipped/flipping): retry
            // after a monitor period.
            let at = self.queue.now() + self.cfg.monitor_interval_us;
            self.queue.schedule_at(at, Event::Arrival(slot));
            return;
        };

        match self.cfg.predictor_mode {
            PredictorMode::Parallel => {
                // Prediction rides alongside; request is immediately
                // schedulable, concurrent chunks pay the Figure 17 tax.
                let dlen = self.requests[slot as usize].req.decode_len;
                let pred = self.predictor.predict(&[], dlen);
                self.requests[slot as usize].req.predicted = Some(pred);
                let meta = self.meta_of(slot);
                let p = self.prefill_mut(i);
                p.pending_pred += 1;
                p.sched.push(meta);
                self.note_prefill_load_increased(i);
                self.try_start_prefill(i, obs);
            }
            PredictorMode::Sequential => {
                let tokens = self.requests[slot as usize].req.prompt_len.min(512);
                let dur = self.cfg.cost.predictor_iter_us(tokens);
                self.queue.schedule_in(dur, Event::PredictDone { instance: i, req: slot });
            }
            PredictorMode::Disabled => {
                let meta = self.meta_of(slot);
                self.prefill_mut(i).sched.push(meta);
                self.note_prefill_load_increased(i);
                self.try_start_prefill(i, obs);
            }
        }
    }

    fn on_predict_done(&mut self, i: usize, slot: ReqId, obs: &mut dyn Observer) {
        let dlen = self.requests[slot as usize].req.decode_len;
        let pred = self.predictor.predict(&[], dlen);
        self.requests[slot as usize].req.predicted = Some(pred);
        let meta = self.meta_of(slot);
        if let InstState::Prefill(p) = &mut self.insts[i] {
            p.sched.push(meta);
            self.note_prefill_load_increased(i);
            self.try_start_prefill(i, obs);
        } else {
            // instance flipped while predicting: re-route
            self.queue.schedule_in(0, Event::Arrival(slot));
        }
    }

    // ----------------------------------------------------------- prefill

    fn prefill_mut(&mut self, i: usize) -> &mut PrefillInst {
        match &mut self.insts[i] {
            InstState::Prefill(p) => p,
            _ => panic!("instance {i} is not a prefill instance"),
        }
    }

    fn try_start_prefill(&mut self, i: usize, obs: &mut dyn Observer) {
        let cap = self.cfg.cost.kv_capacity_tokens();
        let chunk_size = self.cfg.chunk_size;
        let InstState::Prefill(p) = &mut self.insts[i] else { return };
        if p.busy {
            return;
        }
        // Admit scheduled requests into the chunker lazily — just enough
        // to keep the next iterations fed. The backlog stays in the local
        // scheduler where PrefillSchedBatch sorting applies (§3.3.1), and
        // KV backpressure caps residency (prompt KV lives here until
        // transferred out). Moving a request sched → chunker leaves the
        // instance's total load unchanged.
        while p.chunker.pending_tokens() < 2 * chunk_size as u64 {
            let Some(nxt) = p.sched.peek() else { break };
            if p.resident_kv + nxt.prompt_len as u64 > cap {
                break;
            }
            let m = p.sched.pop().unwrap();
            p.resident_kv += m.prompt_len as u64;
            p.chunker.admit(m);
        }
        let Some(chunk) = p.chunker.next_chunk() else { return };
        // Fixed-size iteration, charged by real tokens: the ChunkSize cap
        // is what prevents over-saturated iterations (§3.3.3); the final
        // partial chunk's zero-padding is shape filler, not useful compute
        // (under the paper's stress workloads chunks are full anyway, so
        // this matches their regime — see DESIGN.md §Calibration).
        let mut dur = self.cfg.cost.prefill_iter_us(chunk.tokens);
        if p.pending_pred > 0 {
            dur = (dur as f64 * (1.0 + PARALLEL_PREDICT_OVERHEAD)) as Us;
            p.pending_pred = p.pending_pred.saturating_sub(PREDICTIONS_PER_CHUNK);
        }
        let (tokens, pad) = (chunk.tokens, chunk.pad());
        p.current = Some(chunk);
        p.busy = true;
        p.last_active = self.queue.now();
        self.metrics.busy_us[i] += dur;
        self.queue.schedule_in(dur, Event::PrefillIterDone { instance: i });
        obs.on_chunk(self.queue.now(), i, tokens, pad, dur);
        // slicing the chunk shrank this instance's pending load
        self.note_prefill_load_decreased(i);
    }

    fn on_prefill_done(&mut self, i: usize, obs: &mut dyn Observer) {
        let now = self.queue.now();
        let chunk = {
            let p = self.prefill_mut(i);
            p.busy = false;
            p.last_active = now;
            p.current.take().expect("iteration completed without a chunk")
        };
        for seg in &chunk.segments {
            if !seg.last {
                continue;
            }
            // Request fully prefilled: first token exists now (TTFT).
            let slot = seg.req;
            let epoch = self.insts_epoch[i];
            let st = &mut self.requests[slot as usize];
            st.first_token = now;
            st.prefilled_by = Some((i, epoch));
            if st.req.decode_len <= 1 {
                // prefill's own token completes the request
                self.finish(slot, now, obs);
                self.release_prefill_resident(slot);
                continue;
            }
            // Dispatcher: decentralized inter-decode scheduling over the
            // monitor's last broadcast (§3.3.4).
            if !self.dispatch_request(slot, obs) {
                // No decode instance known (mid-flip window): park the
                // request; the monitor tick retries dispatch.
                self.pending_dispatch.push(slot);
            }
        }
        self.try_start_prefill(i, obs);
    }

    /// The §3.3.4 dispatch: stale broadcast + own recent sends → α/β split
    /// → power-of-two → least interference; then schedule the KV transfer.
    fn dispatch_request(&mut self, slot: ReqId, obs: &mut dyn Observer) -> bool {
        let req = self.requests[slot as usize].req;
        // merge broadcast with what we dispatched since the last tick
        // (into the reusable scratch buffer — this runs once per request)
        self.loads_scratch.clear();
        self.loads_scratch.extend(self.broadcast.iter().map(|l| {
            let (h, lt, kv) = self.since_tick[l.instance];
            DecodeLoad {
                instance: l.instance,
                free_kv_tokens: l.free_kv_tokens.saturating_sub(kv),
                n_heavy: l.n_heavy + h,
                n_light: l.n_light + lt,
                queue_len: l.queue_len + h + lt,
            }
        }));
        let target = choose(
            &self.loads_scratch,
            req.prompt_len,
            req.predicted,
            self.cfg.granularity,
            self.cfg.dispatch,
            &mut self.rng,
        );
        let Some(d) = target else { return false };
        let heavy = req
            .predicted
            .map(|p| p.predicts_heavy(crate::types::HEAVY_DECODE_TOKENS))
            .unwrap_or(false);
        let entry = &mut self.since_tick[d];
        if heavy {
            entry.0 += 1;
        } else {
            entry.1 += 1;
        }
        entry.2 += crate::prefill::predicted_footprint(req.prompt_len, req.predicted, self.cfg.granularity);
        // Exposed transfer latency: request-level ships everything now;
        // chunk-level already overlapped earlier chunks with compute and
        // only the tail chunk's wire time remains visible (§3.3.4).
        let n_chunks = req.prompt_len.div_ceil(self.cfg.chunk_size).max(1);
        let chunk_tokens = req.prompt_len.div_ceil(n_chunks);
        let chunk_compute = self.cfg.cost.prefill_iter_us(self.cfg.chunk_size);
        let dur = self
            .fabric
            .exposed_transfer_us(n_chunks, chunk_tokens, chunk_compute);
        self.queue.schedule_in(dur, Event::TransferDone { instance: d, req: slot });
        obs.on_transfer(self.queue.now(), d, req.id, req.prompt_len, dur);
        true
    }

    // ------------------------------------------------------------ decode

    fn on_transfer_done(&mut self, d: usize, slot: ReqId, obs: &mut dyn Observer) {
        // KV has left the prefill instance: release backpressure there.
        self.release_prefill_resident(slot);

        let req = self.requests[slot as usize].req;
        let meta = self.meta_of(slot);
        match &mut self.insts[d] {
            InstState::Decode(di) => {
                if req.heavy_decode() {
                    self.metrics.decode_assign[d].0 += 1;
                } else {
                    self.metrics.decode_assign[d].1 += 1;
                }
                let mut job = DecodeJob::new(meta, req.decode_len);
                job.generated = 1; // prefill produced the first token
                di.sched.enqueue(job);
                self.try_start_decode(d, obs);
            }
            _ => {
                // Instance flipped away while the KV was in flight: pick a
                // new decode instance and pay the transfer again.
                if !self.dispatch_request(slot, obs) {
                    self.pending_dispatch.push(slot);
                }
            }
        }
    }

    /// Release the prompt KV held on the prefill instance that actually
    /// prefilled this request (recorded at prefill completion, consumed
    /// exactly once). If that instance flipped away while the KV was in
    /// flight, its residency counter died with the role change and there
    /// is nothing to release. Releasing *only* at the recorded instance
    /// keeps the per-instance backpressure signal honest under
    /// multi-prefill configs (previously the subtraction landed on
    /// whichever instance's counter happened to fit).
    fn release_prefill_resident(&mut self, slot: ReqId) {
        let st = &mut self.requests[slot as usize];
        let plen = st.req.prompt_len as u64;
        let Some((i, epoch)) = st.prefilled_by.take() else { return };
        if self.insts_epoch[i] != epoch {
            return; // instance flipped since: that residency died with it
        }
        if let InstState::Prefill(p) = &mut self.insts[i] {
            p.resident_kv = p.resident_kv.saturating_sub(plen);
        }
    }

    fn try_start_decode(&mut self, d: usize, obs: &mut dyn Observer) {
        let cost = self.cfg.cost;
        let now = self.queue.now();
        let InstState::Decode(di) = &mut self.insts[d] else { return };
        if di.busy {
            return;
        }
        let paged_in = di.sched.admit(&mut di.kv);
        if di.sched.n_resident() == 0 {
            return;
        }
        // Execute the iteration's effects now; expose them at IterDone.
        let batch = di.sched.n_resident() as u32;
        let kv_tokens = di.sched.running_kv_tokens();
        di.pending_done.clear();
        let swapped_out = di.sched.step(&mut di.kv, &mut di.pending_done);
        debug_assert!(di.kv.check_invariants().is_ok());
        // Iteration cost: compute + any PCIe swap traffic this iteration
        // (victim page-out now, victim page-in when it re-admits).
        let dur = cost.decode_iter_us(batch, kv_tokens)
            + cost.swap_us(swapped_out)
            + cost.swap_us(paged_in_swapins(paged_in, &di.sched));
        di.busy = true;
        di.last_active = now;
        self.metrics.busy_us[d] += dur;
        self.queue.schedule_in(dur, Event::DecodeIterDone { instance: d });
        obs.on_decode_iter(now, d, batch, kv_tokens, dur);
    }

    fn on_decode_done(&mut self, d: usize, obs: &mut dyn Observer) {
        let now = self.queue.now();
        let mut done = {
            let InstState::Decode(di) = &mut self.insts[d] else { return };
            di.busy = false;
            di.last_active = now;
            std::mem::take(&mut di.pending_done)
        };
        for slot in done.drain(..) {
            self.finish(slot, now, obs);
        }
        // hand the buffer back so the next iteration reuses its capacity
        if let InstState::Decode(di) = &mut self.insts[d] {
            di.pending_done = done;
        }
        self.try_start_decode(d, obs);
    }

    fn finish(&mut self, slot: ReqId, now: Us, obs: &mut dyn Observer) {
        let st = &self.requests[slot as usize];
        let first = if st.first_token == NO_TIME { now } else { st.first_token };
        let rec = RequestRecord {
            id: st.req.id,
            task: st.req.task,
            prompt_len: st.req.prompt_len,
            decode_len: st.req.decode_len,
            arrival: st.req.arrival,
            first_token: first,
            finished: now,
            predicted: st.req.predicted,
        };
        obs.on_finish(now, &rec);
        self.metrics.records.push(rec);
        self.outstanding -= 1;
    }

    // ----------------------------------------------------------- monitor

    fn refresh_broadcast(&mut self) {
        // reuse both buffers — this runs every monitor tick
        for e in self.since_tick.iter_mut() {
            *e = (0, 0, 0);
        }
        self.broadcast.clear();
        for (i, s) in self.insts.iter().enumerate() {
            if let InstState::Decode(di) = s {
                let (h, l) = di.sched.heavy_light();
                self.broadcast.push(DecodeLoad {
                    instance: i,
                    free_kv_tokens: di.kv.free_tokens(),
                    n_heavy: h,
                    n_light: l,
                    queue_len: di.sched.queue_len(),
                });
            }
        }
    }

    fn on_monitor_tick(&mut self, obs: &mut dyn Observer) {
        self.refresh_broadcast();
        obs.on_monitor(self.queue.now(), &self.broadcast);
        self.maybe_flip(obs);
        // Retry any dispatches parked while no decode instance existed.
        for slot in std::mem::take(&mut self.pending_dispatch) {
            if !self.dispatch_request(slot, obs) {
                self.pending_dispatch.push(slot);
            }
        }
        if self.outstanding > 0 {
            self.queue.schedule_in(self.cfg.monitor_interval_us, Event::MonitorTick);
        }
    }

    // -------------------------------------------------------------- flip

    fn maybe_flip(&mut self, obs: &mut dyn Observer) {
        let Some(flip) = self.cfg.flip else { return };
        let now = self.queue.now();
        let n_prefill = self
            .insts
            .iter()
            .filter(|s| matches!(s, InstState::Prefill(_)))
            .count();
        let n_decode = self
            .insts
            .iter()
            .filter(|s| matches!(s, InstState::Decode(_)))
            .count();
        let prefill_pressure: u64 = self
            .insts
            .iter()
            .filter_map(|s| match s {
                InstState::Prefill(p) => Some(p.load()),
                _ => None,
            })
            .sum();
        // Pressure = any live work on the other role (the paper's policy
        // flips on the instance's own idleness; requiring the other role
        // to actually have work avoids useless role churn).
        let decode_pressure: u64 = self
            .insts
            .iter()
            .filter_map(|s| match s {
                InstState::Decode(d) => Some(d.sched.total_jobs() as u64),
                _ => None,
            })
            .sum();

        for i in 0..self.insts.len() {
            match &self.insts[i] {
                InstState::Prefill(p)
                    if !p.busy
                        && p.sched.is_empty()
                        && !p.chunker.has_work()
                        && now.saturating_sub(p.last_active) >= flip.idle_us
                        && n_prefill > flip.min_per_role
                        && decode_pressure > 0 =>
                {
                    // drained already (idle): flip is just the role switch
                    let dur = self.rng.range(flip.flip_min_us, flip.flip_max_us + 1);
                    self.insts[i] = InstState::Flipping { to: Role::Decode };
                    self.insts_epoch[i] += 1;
                    self.least_prefill_dirty = true;
                    self.metrics.flips += 1;
                    self.queue.schedule_in(dur, Event::FlipDone { instance: i });
                    obs.on_flip(now, i, Role::Decode, dur);
                    return; // at most one flip per tick
                }
                InstState::Decode(d)
                    if !d.busy
                        && d.sched.total_jobs() == 0
                        && now.saturating_sub(d.last_active) >= flip.idle_us
                        && n_decode > flip.min_per_role
                        && prefill_pressure > 0 =>
                {
                    let dur = self.rng.range(flip.flip_min_us, flip.flip_max_us + 1);
                    self.insts[i] = InstState::Flipping { to: Role::Prefill };
                    self.insts_epoch[i] += 1;
                    self.metrics.flips += 1;
                    self.queue.schedule_in(dur, Event::FlipDone { instance: i });
                    obs.on_flip(now, i, Role::Prefill, dur);
                    return;
                }
                _ => {}
            }
        }
    }

    fn on_flip_done(&mut self, i: usize) {
        let InstState::Flipping { to } = self.insts[i] else { return };
        self.insts[i] = match to {
            Role::Prefill => InstState::Prefill(new_prefill_inst(&self.cfg, self.queue.now())),
            Role::Decode => InstState::Decode(new_decode_inst(&self.cfg)),
            Role::Coupled => unreachable!(),
        };
        self.least_prefill_dirty = true;
        self.refresh_broadcast();
    }
}

fn new_prefill_inst(cfg: &ClusterConfig, now: Us) -> PrefillInst {
    PrefillInst {
        sched: PrefillScheduler::new(cfg.prefill_policy, cfg.sched_batch),
        chunker: new_chunker(cfg),
        busy: false,
        current: None,
        resident_kv: 0,
        pending_pred: 0,
        last_active: now,
    }
}

fn new_chunker(cfg: &ClusterConfig) -> Chunker {
    if cfg.srtf_chunking {
        Chunker::new_srtf(cfg.chunk_size)
    } else {
        Chunker::new(cfg.chunk_size)
    }
}

fn new_decode_inst(cfg: &ClusterConfig) -> DecodeInst {
    let pages = (cfg.cost.kv_capacity_tokens() / 16) as u32;
    DecodeInst {
        sched: DecodeScheduler::new(cfg.decode_policy, cfg.granularity, cfg.max_batch),
        kv: PagedKvCache::new(pages.max(2), 16),
        busy: false,
        pending_done: Vec::new(),
        last_active: 0,
    }
}

/// Swap-in charge: re-admitted (previously swapped) jobs pay the PCIe
/// fetch; fresh admissions' KV arrived over the fabric and is charged
/// there. We approximate by charging swap cost only when the scheduler has
/// swap history. (Kept as a function for the ablation bench to override.)
fn paged_in_swapins(paged_in: u64, sched: &DecodeScheduler) -> u64 {
    if sched.running_has_swap_history() {
        paged_in
    } else {
        0
    }
}

/// Convenience: run a trace through the cluster driver (the same
/// `api::Driver` the scenario registry resolves for `"tetri"`), with no
/// observer attached.
pub fn run_cluster(cfg: ClusterConfig, trace: Vec<Request>) -> RunMetrics {
    use crate::api::Driver as _;
    crate::api::ClusterDriver::from_config(cfg)
        .run(&trace, &mut NullObserver)
        .metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{WorkloadGen, WorkloadKind};

    fn small_cfg() -> ClusterConfig {
        ClusterConfig { n_prefill: 1, n_decode: 2, flip: None, ..Default::default() }
    }

    #[test]
    fn completes_every_request() {
        let mut gen = WorkloadGen::new(1);
        let trace = gen.trace(WorkloadKind::Mixed, 64, 20.0, 0);
        let m = run_cluster(small_cfg(), trace);
        assert_eq!(m.records.len(), 64);
        assert!(m.events > 64, "every request takes several events");
        for r in &m.records {
            assert!(r.first_token >= r.arrival, "TTFT before arrival");
            assert!(r.finished >= r.first_token, "JCT before TTFT");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut gen = WorkloadGen::new(3);
            run_cluster(small_cfg(), gen.trace(WorkloadKind::Mixed, 32, 50.0, 0))
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.makespan_us, b.makespan_us);
        assert_eq!(a.events, b.events);
        assert!((a.jct_summary().mean - b.jct_summary().mean).abs() < 1e-9);
    }

    #[test]
    fn ttft_beats_jct_ordering_and_busy_time_positive() {
        let mut gen = WorkloadGen::new(5);
        let m = run_cluster(small_cfg(), gen.trace(WorkloadKind::Lpld, 32, 0.0, 0));
        assert!(m.resource_seconds() > 0.0);
        assert!(m.makespan_us > 0);
        assert!(m.ttft_summary().mean <= m.jct_summary().mean);
    }

    #[test]
    fn nvlink_transfers_beat_roce_on_ttft_to_first_decode() {
        let mut gen = WorkloadGen::new(7);
        let trace = gen.trace(WorkloadKind::Lphd, 48, 0.0, 0);
        let roce = run_cluster(ClusterConfig { flip: None, ..ClusterConfig::ts_roce(1, 2) }, trace.clone());
        let nv = run_cluster(ClusterConfig { flip: None, ..ClusterConfig::ts_nvlink(1, 2) }, trace);
        // transfer is off the TTFT path but on the JCT path
        assert!(nv.jct_summary().mean <= roce.jct_summary().mean * 1.01);
    }

    #[test]
    fn flip_activates_under_idle_prefill() {
        let mut gen = WorkloadGen::new(9);
        // decode-heavy workload with a tiny flip threshold: the second
        // prefill instance should flip to decode.
        let cfg = ClusterConfig {
            n_prefill: 2,
            n_decode: 1,
            flip: Some(crate::coordinator::FlipConfig {
                idle_us: 1_000_000,
                ..Default::default()
            }),
            ..Default::default()
        };
        let trace = gen.trace(WorkloadKind::Lphd, 96, 0.0, 0);
        let m = run_cluster(cfg, trace);
        assert_eq!(m.records.len(), 96);
        assert!(m.flips >= 1, "expected at least one prefill→decode flip");
    }

    #[test]
    fn more_decode_instances_reduce_jct_for_heavy_decode() {
        let mut gen = WorkloadGen::new(11);
        let trace = gen.trace(WorkloadKind::Lphd, 128, 0.0, 0);
        let one = run_cluster(ClusterConfig { n_decode: 1, ..small_cfg() }, trace.clone());
        let four = run_cluster(ClusterConfig { n_decode: 4, ..small_cfg() }, trace);
        assert!(
            four.jct_summary().mean < one.jct_summary().mean,
            "scaling decode must help heavy-decode workloads"
        );
    }

    #[test]
    fn records_report_original_request_ids() {
        // Arena slots are internal: records must carry the trace's ids
        // even when they are sparse.
        let mut gen = WorkloadGen::new(13);
        let trace: Vec<Request> = gen
            .trace(WorkloadKind::Lpld, 16, 0.0, 0)
            .into_iter()
            .map(|mut r| {
                r.id += 5_000;
                r
            })
            .collect();
        let m = run_cluster(small_cfg(), trace);
        assert_eq!(m.records.len(), 16);
        for r in &m.records {
            assert!(r.id >= 5_000, "record lost its original id: {}", r.id);
        }
    }

    #[test]
    fn multi_prefill_release_targets_the_prefilling_instance() {
        // Two prefill instances under a standing backlog: the residency
        // counters must drain back to a sane state (the old "subtract
        // wherever it fits" release corrupted them), so the run completes
        // and each instance keeps doing work.
        let mut gen = WorkloadGen::new(17);
        let trace = gen.trace(WorkloadKind::Hpld, 96, 0.0, 0);
        let m = run_cluster(
            ClusterConfig { flip: None, ..ClusterConfig::ts_roce(2, 2) },
            trace,
        );
        assert_eq!(m.records.len(), 96);
        assert!(m.busy_us[0] > 0 && m.busy_us[1] > 0, "both prefill instances must serve");
    }
}
