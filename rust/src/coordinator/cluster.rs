//! Discrete-event TetriInfer cluster: the paper's full pipeline —
//!
//!   arrival → global scheduler (least-load prefill routing, §3.2)
//!           → prefill local scheduler (FCFS/SJF/LJF, §3.3.1)
//!           → length predictor (parallel/sequential, §3.3.2)
//!           → chunked prefill (fixed ChunkSize iterations, §3.3.3)
//!           → dispatcher (power-of-two over broadcast loads, §3.3.4)
//!           → KV transfer over the emulated fabric (Figure 9)
//!           → decode local scheduler (greedy/reserve-*, §3.4)
//!           → continuous-batching decode until completion
//!
//! plus the cluster monitor's periodic load broadcast, instance flipping
//! (§3.5), elastic pool scaling, and — in hybrid mode (`n_coupled > 0`)
//! — coupled vanilla-vLLM instances serving inside the same cluster.
//! Deterministic given (config, trace).
//!
//! Since the instance-engine refactor this file is *policy glue*: the
//! arena request store, event loop and finish bookkeeping live in
//! `sim::EngineCore` (shared with the coupled baseline driver), and the
//! per-role iteration mechanics live in `instance::{PrefillInst,
//! DecodeInst, CoupledInst}` behind `instance::InstancePool`'s role state
//! machine. What remains here is the §3.2 routing, the two-level
//! scheduling decisions, the monitor, and the flip/scale policies.
//!
//! Hot-path layout (see DESIGN.md §Hot paths): events carry dense arena
//! *slots* (no hashing, no `Request` clones), per-instance load is read
//! from O(1) cached counters, the least-loaded prefill choice is served
//! from a dirty-tracked cache, and the monitor tick reuses its
//! `broadcast`/`since_tick` buffers instead of reallocating them.

use std::collections::HashMap;

use crate::api::{NullObserver, Observer};
use crate::decode::DecodeJob;
use crate::fabric::{Fabric, Granularity};
use crate::fault::{scale_dur, FaultPlan, Injection};
use crate::instance::{
    CoupledInst, DecodeInst, DrainTarget, InstancePool, InstanceRole, InstanceState, PrefillInst,
};
use crate::metrics::RunMetrics;
use crate::predictor::{OraclePredictor, Predictor};
use crate::prefill::{choose_ranked, predicted_footprint, DecodeLoad};
use crate::prefixcache::{block_hashes, Pin, PrefixCache};
use crate::slo::AdmissionGate;
use crate::sim::{
    macro_chain, run_des, run_des_source, ArrivalSource, EngineCore, EngineHost, Event, HotState,
};
use crate::types::{ReqId, ReqMeta, Request, Role, Us, HEAVY_DECODE_TOKENS};
use crate::util::Pcg;

use super::config::{ClusterConfig, PredictorMode};

/// Which entry point an arrival is routed to (hybrid clusters have two).
enum Entry {
    Prefill(usize),
    Coupled(usize),
}

/// Per-run reusable buffers for coordinator paths that would otherwise
/// allocate per event — part of the zero-alloc steady-state invariant
/// (DESIGN.md §Performance rule 5; enforced by the `alloc-count`
/// feature). Instance-side assembly/harvest buffers live inside the role
/// states themselves (`pending_prefilled`, `done`, ...).
struct Scratch {
    /// Merged broadcast + since-tick load view, rebuilt per dispatch.
    loads: Vec<DecodeLoad>,
    /// The monitor tick's parked-dispatch retry sweep: swapped with
    /// `pending_dispatch` so both vectors keep their capacity.
    dispatch: Vec<ReqId>,
}

pub struct Cluster {
    pub cfg: ClusterConfig,
    /// Shared DES engine: queue + arena + metrics + termination.
    core: EngineCore,
    /// The elastic instance pool (role state machines + epochs).
    pool: InstancePool,
    /// Last monitor broadcast of decode loads (stale by design, §3.2).
    /// Buffer reused across ticks.
    broadcast: Vec<DecodeLoad>,
    /// What this coordinator's dispatchers sent since the last broadcast:
    /// (heavy, light, kv footprint) per instance. A real dispatcher knows
    /// its own recent sends even though the broadcast is stale.
    since_tick: Vec<(u32, u32, u64)>,
    /// Reusable hot-path buffers (see [`Scratch`]).
    scratch: Scratch,
    /// Cached least-loaded prefill instance (the §3.2 routing target).
    /// Invalidated when the cached instance's load grows or the instance
    /// set changes; kept fresh in O(1) when any other instance's load
    /// drops below it.
    least_prefill: Option<usize>,
    least_prefill_dirty: bool,
    predictor: OraclePredictor,
    fabric: Fabric,
    rng: Pcg,
    /// Prefilled requests awaiting a dispatch target (mid-flip windows).
    pending_dispatch: Vec<ReqId>,
    /// Arrivals not yet enqueued into any local scheduler (coupled
    /// partial prefill batches wait on these — vanilla vLLM semantics).
    arrivals_pending: usize,
    /// Swap tallies of role states that already left the pool (flips,
    /// drains, retirements) — folded into `swapped_tokens` at run end so
    /// they don't die with the role.
    swapped_graveyard: u64,
    /// SLO admission gate at the entry router (`None` = admission off —
    /// the classless hot path never consults it). One deterministic
    /// decision per request, at its first arrival delivery.
    gate: Option<AdmissionGate>,
    /// Deterministic chaos schedule + recovery policy (`None` = fault-free
    /// — every fault path below is gated on it, so the fault-free
    /// trajectory is bit-identical to pre-fault builds).
    plan: Option<FaultPlan>,
    /// When the cluster entered degraded mode (live capacity below the
    /// plan's watermark); folded into `metrics.degraded_us` on exit.
    degraded_since: Option<Us>,
    /// Role-serving instances at run start — the denominator the degraded
    /// watermark is measured against.
    base_capacity: usize,
    /// Per-slot prefix caches (one per pool slot, same indexing). Empty
    /// when `cfg.prefix_cache` is `None` — the cache-off hot path never
    /// touches them. A slot's cache follows its instance through role
    /// flips and crashes (invalidated when the KV dies), so the
    /// cumulative hit/miss ledger survives incarnations.
    prefix_caches: Vec<PrefixCache>,
    /// Pins taken at prefix-cache admission, held until the request's
    /// prefill completes (or a fault re-queues it): slot → (instance,
    /// pin). Accessed by key only — iteration order never observed.
    prefix_pins: HashMap<ReqId, (usize, Pin)>,
    /// Prefill tokens skipped per in-flight request (the cached-prefix
    /// depth after clamping): the KV-residency release must subtract
    /// these, because only the suffix was admitted.
    prefix_saved: HashMap<ReqId, u32>,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        let mut pool = InstancePool::new();
        for _ in 0..cfg.n_prefill {
            pool.push(InstanceState::Prefill(new_prefill_inst(&cfg, 0)));
        }
        for _ in 0..cfg.n_decode {
            pool.push(InstanceState::Decode(new_decode_inst(&cfg)));
        }
        for _ in 0..cfg.n_coupled {
            pool.push(InstanceState::Coupled(new_coupled_inst(&cfg)));
        }
        let n = pool.len();
        let predictor = OraclePredictor::new(
            cfg.granularity,
            cfg.n_buckets,
            if cfg.predictor_mode == PredictorMode::Disabled { 0.0 } else { cfg.predictor_accuracy },
            cfg.seed ^ 0xabcd,
        );
        let mut fabric = Fabric::new(cfg.link, cfg.cost.kv_bytes_per_tok);
        fabric.granularity = cfg.transfer_granularity;
        let rng = Pcg::with_stream(cfg.seed, 0x1234_5678_9abc_def1);
        let mut core = EngineCore::new(n);
        core.metrics.retain_records = cfg.retain_records;
        core.stop = cfg.stop;
        if cfg.profile_events {
            core.profile = Some(Box::default());
        }
        // the metrics need the class table at finish time (attainment);
        // this also pre-sizes the per-class ledger so zero-traffic
        // tenants still report
        core.metrics.set_classes(cfg.slo.classes.clone());
        let gate = AdmissionGate::from_config(&cfg.slo);
        let plan = cfg.fault.clone().map(|fc| FaultPlan::new(fc, cfg.seed));
        let prefix_caches = match cfg.prefix_cache {
            Some(pc) => (0..n).map(|_| PrefixCache::new(pc)).collect(),
            None => Vec::new(),
        };
        Cluster {
            cfg,
            core,
            pool,
            broadcast: Vec::new(),
            since_tick: vec![(0, 0, 0); n],
            scratch: Scratch { loads: Vec::with_capacity(n), dispatch: Vec::new() },
            least_prefill: None,
            least_prefill_dirty: true,
            predictor,
            fabric,
            rng,
            pending_dispatch: Vec::new(),
            arrivals_pending: 0,
            swapped_graveyard: 0,
            gate,
            plan,
            degraded_since: None,
            base_capacity: 0,
            prefix_caches,
            prefix_pins: HashMap::new(),
            prefix_saved: HashMap::new(),
        }
    }

    /// Run a trace to completion; returns final metrics.
    pub fn run(self, trace: Vec<Request>) -> RunMetrics {
        self.run_observed(trace, &mut NullObserver)
    }

    /// Run a trace to completion, streaming per-event hooks to `obs`.
    /// The observer never influences the run: metrics are bit-identical
    /// to `run` (golden-tested through `api::Scenario`).
    pub fn run_observed(mut self, trace: Vec<Request>, obs: &mut dyn Observer) -> RunMetrics {
        run_des(&mut self, trace, obs)
    }

    /// Run a pull-based arrival stream to completion — the O(active)-memory
    /// hot path scale runs use (identical trajectory to `run_observed` on
    /// the materialized trace; parity-tested in tests/golden.rs).
    pub fn run_streamed(mut self, source: &mut dyn ArrivalSource, obs: &mut dyn Observer) -> RunMetrics {
        run_des_source(&mut self, source, obs)
    }

    // --------------------------------------------- least-loaded prefill

    /// Load of instance `i` iff it is a prefill instance accepting work.
    fn prefill_load_of(&self, i: usize) -> Option<u64> {
        if !self.pool.accepts_work(i) {
            return None;
        }
        match self.pool.state(i) {
            InstanceState::Prefill(p) => Some(p.load()),
            _ => None,
        }
    }

    /// The cached instance's load grew (a request was routed to it): the
    /// cache may no longer be the minimum.
    fn note_prefill_load_increased(&mut self, i: usize) {
        if self.least_prefill == Some(i) {
            self.least_prefill_dirty = true;
        }
    }

    /// Instance `i`'s load shrank (a chunk was sliced off): it may now
    /// undercut the cached minimum. Same tie-break as the full scan
    /// (lowest index among minima), so cache hits and rescans agree.
    fn note_prefill_load_decreased(&mut self, i: usize) {
        if self.least_prefill_dirty {
            return;
        }
        let Some(j) = self.least_prefill else {
            self.least_prefill_dirty = true;
            return;
        };
        if i == j {
            return; // the minimum got smaller: still the minimum
        }
        let (Some(li), Some(lj)) = (self.prefill_load_of(i), self.prefill_load_of(j)) else {
            self.least_prefill_dirty = true;
            return;
        };
        if li < lj || (li == lj && i < j) {
            self.least_prefill = Some(i);
        }
    }

    /// Least-loaded prefill instance (§3.2 "choose a prefill instance with
    /// the least load"). Serves from the cache when clean; otherwise one
    /// O(n_instances) pass over the O(1) load counters. Draining
    /// instances are skipped — they take no new work.
    fn pick_prefill(&mut self) -> Option<usize> {
        if !self.least_prefill_dirty {
            if let Some(i) = self.least_prefill {
                if self.prefill_load_of(i).is_some() {
                    return Some(i);
                }
            }
        }
        let mut best: Option<(usize, u64)> = None;
        for i in 0..self.pool.len() {
            if let Some(load) = self.prefill_load_of(i) {
                if best.map(|(_, bl)| load < bl).unwrap_or(true) {
                    best = Some((i, load));
                }
            }
        }
        self.least_prefill = best.map(|(i, _)| i);
        self.least_prefill_dirty = false;
        self.least_prefill
    }

    /// Least-loaded coupled instance accepting work (hybrid mode only).
    fn pick_coupled(&self) -> Option<usize> {
        let mut best: Option<(usize, u64)> = None;
        for (i, inst) in self.pool.iter().enumerate() {
            if !inst.accepts_work() {
                continue;
            }
            if let InstanceState::Coupled(c) = &inst.state {
                let load = c.route_load();
                if best.map(|(_, bl)| load < bl).unwrap_or(true) {
                    best = Some((i, load));
                }
            }
        }
        best.map(|(i, _)| i)
    }

    // ------------------------------------------------------ prefix cache

    /// Cache-aware §3.2 routing: the prefill instance holding the longest
    /// resident match for this request's prefix wins; with no resident
    /// match anywhere (or no stamp, or cache off) the pick falls back to
    /// the least-loaded path. Ties break by load, then lowest index —
    /// fully deterministic.
    fn pick_prefill_for(&mut self, slot: ReqId) -> Option<usize> {
        if !self.prefix_caches.is_empty() {
            if let (Some(stamp), Some(pc)) =
                (self.core.requests[slot as usize].prefix, self.cfg.prefix_cache)
            {
                let plen = self.core.requests[slot as usize].prompt_len;
                let hashes = block_hashes(stamp.id, stamp.len.min(plen), pc.block_tokens);
                let mut best: Option<(u32, u64, usize)> = None;
                for i in 0..self.pool.len() {
                    let Some(load) = self.prefill_load_of(i) else { continue };
                    let depth = self.prefix_caches[i].peek(&hashes);
                    let better = match best {
                        None => true,
                        Some((bd, bl, _)) => depth > bd || (depth == bd && load < bl),
                    };
                    if better {
                        best = Some((depth, load, i));
                    }
                }
                if let Some((depth, _, i)) = best {
                    if depth > 0 {
                        return Some(i);
                    }
                    // cold everywhere: take the cached least-loaded path
                    // so cache-off and cold-cache routing agree exactly
                }
            }
        }
        self.pick_prefill()
    }

    /// Prefix-cache admission on instance `i`: pin the resident prefix,
    /// count the hit/miss, and shrink the scheduler-facing prompt to the
    /// uncached suffix — the cached chunks skip prefill entirely. Clamped
    /// to `prompt_len - 1` so every request still produces its first
    /// token through a real `PrefillIterDone`.
    fn cache_admit(
        &mut self,
        i: usize,
        slot: ReqId,
        mut meta: ReqMeta,
        obs: &mut dyn Observer,
    ) -> ReqMeta {
        let Some(pc) = self.cfg.prefix_cache else { return meta };
        let Some(stamp) = meta.prefix else { return meta };
        let Some(cache) = self.prefix_caches.get_mut(i) else { return meta };
        let hashes = block_hashes(stamp.id, stamp.len.min(meta.prompt_len), pc.block_tokens);
        let pin = cache.lookup_pin(&hashes);
        let saved =
            cache.tokens_for_depth(pin.depth()).min(meta.prompt_len.saturating_sub(1));
        cache.note_saved(saved as u64);
        obs.on_cache(self.core.now(), self.core.requests[slot as usize].id, saved);
        if let Some((ci, old)) = self.prefix_pins.insert(slot, (i, pin)) {
            // a fault-requeued request can still hold its earlier pin
            if let Some(c) = self.prefix_caches.get_mut(ci) {
                c.release(old);
            }
        }
        if saved > 0 {
            self.prefix_saved.insert(slot, saved);
            meta.prompt_len -= saved;
        } else {
            self.prefix_saved.remove(&slot);
        }
        meta
    }

    /// A request finished prefilling on `i`: its whole prompt (cached
    /// prefix + computed suffix) is resident there now. Release the
    /// admission pin and index the prefix blocks for future arrivals.
    fn cache_index_prefilled(&mut self, i: usize, slot: ReqId) {
        let Some(pc) = self.cfg.prefix_cache else { return };
        self.cache_release_pin(slot);
        let req = &self.core.requests[slot as usize];
        let Some(stamp) = req.prefix else { return };
        let hashes = block_hashes(stamp.id, stamp.len.min(req.prompt_len), pc.block_tokens);
        if let Some(c) = self.prefix_caches.get_mut(i) {
            c.insert(&hashes);
        }
    }

    /// Drop a request's admission pin, if any (no-op after the holding
    /// cache was crash-invalidated — the pin's epoch went stale).
    fn cache_release_pin(&mut self, slot: ReqId) {
        if let Some((ci, pin)) = self.prefix_pins.remove(&slot) {
            if let Some(c) = self.prefix_caches.get_mut(ci) {
                c.release(pin);
            }
        }
    }

    /// Instance `i`'s KV died (crash) or left with its role (flip,
    /// retirement): every cached block on it is gone. Epoch-tagged, so
    /// pins still in flight release as no-ops.
    fn cache_invalidate(&mut self, i: usize) {
        if let Some(c) = self.prefix_caches.get_mut(i) {
            c.invalidate();
        }
    }

    // ----------------------------------------------------------- arrival

    fn on_arrival(&mut self, slot: ReqId, obs: &mut dyn Observer) {
        // One admission decision per request, at its *first* delivery —
        // mid-flip retries re-enqueue `Event::Arrival` and must not
        // re-charge the token bucket.
        let first_delivery = !self.core.seen(slot);
        self.core.note_arrival(slot, obs);
        if first_delivery {
            if let Some(gate) = self.gate.as_mut() {
                let req = self.core.requests[slot as usize];
                // in-flight excluding the arrival under decision: the
                // engine admitted it into the arena before dispatching
                let in_flight = (self.core.in_flight() - 1) as u64;
                if !gate.admits(req.class, self.core.now(), in_flight) {
                    self.core.shed(slot, obs);
                    // the request leaves the global queue without ever
                    // reaching a local scheduler: unblock coupled
                    // partial batches exactly like a routed arrival
                    self.note_enqueued(obs);
                    return;
                }
            }
            // Graceful degradation: below the fault plan's capacity
            // watermark, best-effort tiers are shed at the door so the
            // surviving instances keep serving interactive traffic.
            if self.degraded_since.is_some() {
                let class = self.core.requests[slot as usize].class;
                let tier =
                    self.cfg.slo.classes.get(class as usize).map(|c| c.tier).unwrap_or(0);
                if tier != 0 {
                    self.core.shed(slot, obs);
                    self.note_enqueued(obs);
                    return;
                }
            }
        }
        // The coupled scan only exists in hybrid mode — a pure
        // disaggregated pool can never gain coupled instances mid-run,
        // so the arrival hot path stays on the O(1) prefill cache.
        let coupled = if self.cfg.n_coupled == 0 { None } else { self.pick_coupled() };
        let entry = match (self.pick_prefill_for(slot), coupled) {
            (Some(i), None) => Entry::Prefill(i),
            (None, Some(c)) => Entry::Coupled(c),
            (Some(i), Some(c)) => {
                // Hybrid routing: both architectures expose a
                // token-denominated entry load; the arrival takes the
                // emptier front door (prefill wins ties — the
                // disaggregated path is the paper's default).
                let pl = self.prefill_load_of(i).unwrap_or(u64::MAX);
                let cl = match self.pool.state(c) {
                    InstanceState::Coupled(ci) => ci.route_load(),
                    _ => u64::MAX,
                };
                if pl <= cl { Entry::Prefill(i) } else { Entry::Coupled(c) }
            }
            (None, None) => {
                // No entry point right now. Mid-flip windows heal on
                // their own: retry after a monitor period. Under a fault
                // plan with no restart pending, the hole may be permanent
                // — burn retry budget (with backoff) so the request
                // either finds capacity that elasticity rebuilds or fails
                // bounded, instead of looping forever.
                if self.plan.is_some() && !self.pool.any_restart_pending() {
                    self.requeue_lost(slot, true, obs);
                } else {
                    let at = self.core.now() + self.cfg.monitor_interval_us;
                    self.core.queue.schedule_at(at, Event::Arrival(slot));
                }
                return;
            }
        };
        match entry {
            Entry::Prefill(i) => self.route_to_prefill(slot, i, obs),
            Entry::Coupled(c) => self.route_to_coupled(slot, c, obs),
        }
    }

    fn route_to_prefill(&mut self, slot: ReqId, i: usize, obs: &mut dyn Observer) {
        match self.cfg.predictor_mode {
            PredictorMode::Parallel => {
                // Prediction rides alongside; request is immediately
                // schedulable, concurrent chunks pay the Figure 17 tax.
                let dlen = self.core.requests[slot as usize].decode_len;
                let pred = self.predictor.predict(&[], dlen);
                self.core.requests[slot as usize].predicted = Some(pred);
                let meta = self.core.meta_of(slot);
                let meta = self.cache_admit(i, slot, meta, obs);
                let p = self.pool.prefill_mut(i).expect("routed to a prefill instance");
                p.pending_pred += 1;
                p.sched.push(meta);
                self.note_prefill_load_increased(i);
                self.note_enqueued(obs);
                self.try_start_prefill(i, obs);
            }
            PredictorMode::Sequential => {
                let tokens = self.core.requests[slot as usize].prompt_len.min(512);
                let dur = self.cfg.cost.predictor_iter_us(tokens);
                let epoch = self.pool.epoch(i);
                obs.on_predict(self.core.now(), self.core.requests[slot as usize].id, dur);
                self.core
                    .queue
                    .schedule_in(dur, Event::PredictDone { instance: i, epoch, req: slot });
            }
            PredictorMode::Disabled => {
                let meta = self.core.meta_of(slot);
                let meta = self.cache_admit(i, slot, meta, obs);
                let p = self.pool.prefill_mut(i).expect("routed to a prefill instance");
                p.sched.push(meta);
                self.note_prefill_load_increased(i);
                self.note_enqueued(obs);
                self.try_start_prefill(i, obs);
            }
        }
    }

    fn route_to_coupled(&mut self, slot: ReqId, c: usize, obs: &mut dyn Observer) {
        let plen = self.core.requests[slot as usize].prompt_len;
        let ci = self.pool.coupled_mut(c).expect("routed to a coupled instance");
        ci.enqueue(slot, plen);
        self.note_enqueued(obs);
        self.try_start_coupled(c, obs);
    }

    /// A request left the global queue into a local scheduler. The last
    /// one unblocks coupled partial prefill batches everywhere (mirrors
    /// the standalone baseline's last-arrival kick).
    fn note_enqueued(&mut self, obs: &mut dyn Observer) {
        self.arrivals_pending -= 1;
        if self.arrivals_pending == 0 && self.cfg.n_coupled > 0 {
            for c in 0..self.pool.len() {
                if matches!(self.pool.state(c), InstanceState::Coupled(_)) {
                    self.try_start_coupled(c, obs);
                }
            }
        }
    }

    fn on_predict_done(&mut self, i: usize, epoch: u32, slot: ReqId, obs: &mut dyn Observer) {
        let dlen = self.core.requests[slot as usize].decode_len;
        let pred = self.predictor.predict(&[], dlen);
        self.core.requests[slot as usize].predicted = Some(pred);
        let meta = self.core.meta_of(slot);
        if self.pool.epoch(i) == epoch
            && self.pool.accepts_work(i)
            && self.pool.prefill_mut(i).is_some()
        {
            let meta = self.cache_admit(i, slot, meta, obs);
            let p = self.pool.prefill_mut(i).expect("prefill role checked above");
            p.sched.push(meta);
            self.note_prefill_load_increased(i);
            self.note_enqueued(obs);
            self.try_start_prefill(i, obs);
            return;
        }
        // instance flipped, began draining, or crashed while predicting:
        // re-route (the epoch check keeps a restarted incarnation from
        // inheriting its predecessor's in-flight predictions)
        self.core.queue.schedule_in(0, Event::Arrival(slot));
    }

    // ----------------------------------------------------------- prefill

    fn try_start_prefill(&mut self, i: usize, obs: &mut dyn Observer) {
        let cap = self.cfg.cost.kv_capacity_tokens();
        let chunk_size = self.cfg.chunk_size;
        let cost = self.cfg.cost;
        let now = self.core.now();
        let slow = self.plan.as_ref().map(|p| p.slowdown(i, now)).unwrap_or(1.0);
        let epoch = self.pool.epoch(i);
        let Some(p) = self.pool.prefill_mut(i) else { return };
        if p.busy {
            return;
        }
        p.admit_ready(chunk_size, cap);
        let Some((tokens, pad, dur)) = p.begin_chunk(&cost, now) else { return };
        let dur = scale_dur(dur, slow);
        self.core.metrics.busy_us[i] += dur;
        self.core.queue.schedule_in(dur, Event::PrefillIterDone { instance: i, epoch });
        obs.on_chunk(now, i, tokens, pad, dur);
        // Requests whose first tokens entered this chunk open their
        // prefill span (a segment with start == 0 is its request's first
        // inclusion in any chunk).
        if let Some(p) = self.pool.prefill_mut(i) {
            for seg in p.in_flight_segments() {
                if seg.start == 0 {
                    obs.on_prefill_start(now, i, self.core.requests[seg.req as usize].id);
                }
            }
        }
        // slicing the chunk shrank this instance's pending load
        self.note_prefill_load_decreased(i);
    }

    fn on_prefill_done(&mut self, i: usize, epoch: u32, obs: &mut dyn Observer) {
        if self.pool.epoch(i) != epoch {
            // the instance crashed mid-iteration: its work (and the
            // requests in it) was harvested at crash time — nothing here
            // may touch the restarted incarnation. Fault-free this never
            // fires: a busy instance cannot flip.
            return;
        }
        let now = self.core.now();
        let chunk = {
            let p = self
                .pool
                .prefill_mut(i)
                .expect("prefill iteration completed on a non-prefill instance");
            p.end_chunk(now)
        };
        for seg in &chunk.segments {
            if !seg.last {
                continue;
            }
            // Request fully prefilled: first token exists now (TTFT).
            let slot = seg.req;
            obs.on_prefill_finish(now, i, self.core.requests[slot as usize].id);
            let epoch = self.pool.epoch(i);
            self.core.hot[slot as usize] =
                HotState { first_token: now, prefilled_by: Some((i, epoch)) };
            let done_at_prefill = self.core.requests[slot as usize].decode_len <= 1;
            // whole prompt resident here now: unpin + index the prefix
            self.cache_index_prefilled(i, slot);
            if done_at_prefill {
                // prefill's own token completes the request (release the
                // residency first: finish recycles the arena slot)
                self.release_prefill_resident(slot);
                self.core.finish(slot, now, obs);
                continue;
            }
            // Dispatcher: decentralized inter-decode scheduling over the
            // monitor's last broadcast (§3.3.4).
            if !self.dispatch_request(slot, obs) {
                // No decode instance known (mid-flip window): park the
                // request; the monitor tick retries dispatch.
                obs.on_parked(now, self.core.requests[slot as usize].id);
                self.pending_dispatch.push(slot);
            }
        }
        self.try_start_prefill(i, obs);
    }

    /// The §3.3.4 dispatch: stale broadcast + own recent sends → α/β split
    /// → power-of-two → least interference; then schedule the KV transfer.
    fn dispatch_request(&mut self, slot: ReqId, obs: &mut dyn Observer) -> bool {
        let req = self.core.requests[slot as usize];
        // merge broadcast with what we dispatched since the last tick
        // (into the reusable scratch buffer — this runs once per request)
        self.scratch.loads.clear();
        self.scratch.loads.extend(self.broadcast.iter().map(|l| {
            let (h, lt, kv) = self.since_tick[l.instance];
            DecodeLoad {
                instance: l.instance,
                free_kv_tokens: l.free_kv_tokens.saturating_sub(kv),
                n_heavy: l.n_heavy + h,
                n_light: l.n_light + lt,
                queue_len: l.queue_len + h + lt,
            }
        }));
        // SLO classes with a TPOT deadline rank the power-of-two pair by
        // predicted iteration time on the cost model (resident KV from
        // the broadcast + this request's predicted footprint): hotspot
        // avoidance becomes violation avoidance. Classless requests (and
        // classes without a TPOT target) take the paper's pure
        // least-interference pick — same RNG draws either way.
        let cost = self.cfg.cost;
        let cap = cost.kv_capacity_tokens();
        let footprint =
            predicted_footprint(req.prompt_len, req.predicted, self.cfg.granularity);
        let tpot_est = move |l: &DecodeLoad| -> Us {
            let resident = cap.saturating_sub(l.free_kv_tokens);
            cost.decode_iter_us(l.n_heavy + l.n_light + 1, resident + footprint)
        };
        let slo_ranked = self.cfg.slo.tpot_deadline_us(req.class).is_some();
        let target = choose_ranked(
            &self.scratch.loads,
            req.prompt_len,
            req.predicted,
            self.cfg.granularity,
            self.cfg.dispatch,
            &mut self.rng,
            if slo_ranked { Some(&tpot_est) } else { None },
        );
        let Some(d) = target else { return false };
        let heavy = req
            .predicted
            .map(|p| p.predicts_heavy(HEAVY_DECODE_TOKENS))
            .unwrap_or(false);
        let entry = &mut self.since_tick[d];
        if heavy {
            entry.0 += 1;
        } else {
            entry.1 += 1;
        }
        entry.2 += predicted_footprint(req.prompt_len, req.predicted, self.cfg.granularity);
        let now = self.core.now();
        let nominal = self.transfer_nominal(req.prompt_len);
        // Overlapped granularities hide wire time behind prefill compute;
        // the hidden share (vs shipping everything after the last chunk)
        // is the run's overlap win. Counted once, at first dispatch.
        if self.fabric.granularity != Granularity::RequestLevel {
            self.core.metrics.overlap_us +=
                self.fabric.request_transfer_us(req.prompt_len).saturating_sub(nominal);
        }
        // Open fault windows reprice the wire: a degradation stretches
        // the transfer, an outage delays the send to the window's close.
        let dur = match self.plan.as_ref() {
            Some(p) => p.link_transfer_us(now, nominal),
            None => nominal,
        };
        let epoch = self.pool.epoch(d);
        self.core.queue.schedule_in(dur, Event::TransferDone { instance: d, epoch, req: slot });
        obs.on_transfer(now, d, req.id, req.prompt_len, dur);
        true
    }

    /// Fault-free exposed transfer latency for a prompt (§3.3.4):
    /// request-level ships everything now; chunk-level already overlapped
    /// earlier chunks with compute and only the tail chunk's wire time
    /// remains visible.
    fn transfer_nominal(&self, prompt_len: u32) -> Us {
        let n_chunks = prompt_len.div_ceil(self.cfg.chunk_size).max(1);
        let chunk_tokens = prompt_len.div_ceil(n_chunks);
        let chunk_compute = self.cfg.cost.prefill_iter_us(self.cfg.chunk_size);
        self.fabric.exposed_transfer_us(n_chunks, chunk_tokens, chunk_compute)
    }

    // ------------------------------------------------------------ decode

    fn on_transfer_done(&mut self, d: usize, epoch: u32, slot: ReqId, obs: &mut dyn Observer) {
        let now = self.core.now();
        // A transfer completing inside a link-outage window never made it:
        // the bytes re-send once the window closes (the source still holds
        // the KV — backpressure stays until the payload really lands).
        if let Some(p) = self.plan.as_ref() {
            if p.link_outage_until(now).is_some() {
                let plen = self.core.requests[slot as usize].prompt_len;
                let nominal = self.transfer_nominal(plen);
                let dur =
                    self.plan.as_ref().map(|p| p.link_transfer_us(now, nominal)).unwrap_or(nominal);
                self.core.metrics.transfer_resends += 1;
                obs.on_recovery(now, "resend", None);
                self.core
                    .queue
                    .schedule_in(dur, Event::TransferDone { instance: d, epoch, req: slot });
                return;
            }
        }
        // KV has left the prefill instance: release backpressure there.
        self.release_prefill_resident(slot);
        if self.pool.epoch(d) != epoch {
            // The destination crashed while the KV was in flight: the
            // payload never landed and the restarted incarnation must not
            // inherit it. Pick a new decode instance, pay the wire again.
            if !self.dispatch_request(slot, obs) {
                obs.on_parked(now, self.core.requests[slot as usize].id);
                self.pending_dispatch.push(slot);
            }
            return;
        }

        let req = self.core.requests[slot as usize];
        let meta = self.core.meta_of(slot);
        // A draining decode instance still accepts KV that was already in
        // flight toward it (rejecting would pay the transfer twice).
        let accepted = match self.pool.decode_mut(d) {
            Some(di) => {
                let mut job = DecodeJob::new(meta, req.decode_len);
                job.generated = 1; // prefill produced the first token
                di.sched.enqueue(job);
                obs.on_decode_enter(now, d, req.id);
                true
            }
            None => false,
        };
        if accepted {
            if req.heavy_decode() {
                self.core.metrics.decode_assign[d].0 += 1;
            } else {
                self.core.metrics.decode_assign[d].1 += 1;
            }
            self.try_start_decode(d, obs);
        } else {
            // Instance flipped away while the KV was in flight: pick a
            // new decode instance and pay the transfer again.
            if !self.dispatch_request(slot, obs) {
                obs.on_parked(now, self.core.requests[slot as usize].id);
                self.pending_dispatch.push(slot);
            }
        }
    }

    /// Release the prompt KV held on the prefill instance that actually
    /// prefilled this request (recorded at prefill completion, consumed
    /// exactly once). If that instance left its role while the KV was in
    /// flight, its residency counter died with the role change and there
    /// is nothing to release — the epoch check catches reborn
    /// incarnations. Releasing *only* at the recorded instance keeps the
    /// per-instance backpressure signal honest under multi-prefill
    /// configs.
    fn release_prefill_resident(&mut self, slot: ReqId) {
        let plen = self.core.requests[slot as usize].prompt_len as u64;
        let held = self.core.hot[slot as usize].prefilled_by.take();
        // only the uncached suffix was admitted into residency; any
        // cache-skip note is consumed here whether or not the release
        // itself still applies (fault re-queues re-pin from scratch)
        let saved = self.prefix_saved.remove(&slot).unwrap_or(0) as u64;
        let Some((i, epoch)) = held else { return };
        if self.pool.epoch(i) != epoch {
            return; // instance left its role since: that residency died with it
        }
        if let Some(p) = self.pool.prefill_mut(i) {
            p.release_resident(plen - saved);
        }
    }

    /// Begin one decode iteration on `d` at virtual time `now`: run its
    /// effects, account busy time, fire the observer hook. Returns the
    /// iteration's end time — the *one* copy of iteration start shared by
    /// the arrival-triggered path ([`Cluster::try_start_decode`], which
    /// schedules the completion event) and the macro-step chain (which
    /// may process it inline) — or `None` when the instance is busy, has
    /// nothing resident, or no longer serves the decode role.
    fn start_decode_iteration(&mut self, d: usize, now: Us, obs: &mut dyn Observer) -> Option<Us> {
        let cost = self.cfg.cost;
        // straggler windows are pure functions of `now`, so macro-stepped
        // and per-iteration runs price them identically
        let slow = self.plan.as_ref().map(|p| p.slowdown(d, now)).unwrap_or(1.0);
        let di = self.pool.decode_mut(d)?;
        let st = di.begin_iteration(&cost, now)?;
        let dur = scale_dur(st.dur, slow);
        self.core.metrics.busy_us[d] += dur;
        obs.on_decode_iter(now, d, st.batch, st.kv_tokens, dur);
        Some(now + dur)
    }

    fn try_start_decode(&mut self, d: usize, obs: &mut dyn Observer) {
        let now = self.core.now();
        if let Some(end) = self.start_decode_iteration(d, now, obs) {
            let epoch = self.pool.epoch(d);
            self.core.queue.schedule_at(end, Event::DecodeIterDone { instance: d, epoch });
        }
    }

    /// Close the decode iteration that just ended on `d` at virtual time
    /// `now`: record completions and hand the buffer back for reuse.
    /// No-op when the instance left the decode role mid-flight.
    fn close_decode_iteration(&mut self, d: usize, now: Us, obs: &mut dyn Observer) {
        let Some(di) = self.pool.decode_mut(d) else { return };
        let mut done = di.end_iteration(now);
        for slot in done.drain(..) {
            self.core.finish(slot, now, obs);
        }
        if let Some(di) = self.pool.decode_mut(d) {
            di.return_done_buf(done);
        }
    }

    /// Iteration-complete handler: the decode instantiation of the shared
    /// [`macro_chain`] scaffold — successive iterations run inline while
    /// nothing external can land in the window (the batch composition
    /// provably cannot change there), event-for-event identical to
    /// per-iteration stepping (parity-tested in tests/golden.rs).
    fn on_decode_done(&mut self, d: usize, epoch: u32, obs: &mut dyn Observer) {
        if self.pool.epoch(d) != epoch {
            // crashed mid-iteration: the batch was harvested at crash
            // time; nothing here may land on the restarted incarnation
            return;
        }
        let macro_on = self.cfg.macro_step;
        macro_chain(
            self,
            macro_on,
            obs,
            |s, now, obs| s.close_decode_iteration(d, now, obs),
            |s, now, obs| s.start_decode_iteration(d, now, obs),
            |s, end| {
                let epoch = s.pool.epoch(d);
                s.core.queue.schedule_at(end, Event::DecodeIterDone { instance: d, epoch })
            },
        );
    }

    // ----------------------------------------------------------- coupled

    /// Begin one mixed coupled iteration on `c` at virtual time `now` —
    /// the decode counterpart of [`Cluster::start_decode_iteration`]:
    /// the single copy of iteration start shared by the arrival path and
    /// the macro-step chain. One mixed iteration = a prefill side and a
    /// decode side sharing `dur`; each observer hook fires only when its
    /// side is non-empty. Returns the iteration's end time.
    fn start_coupled_iteration(&mut self, c: usize, now: Us, obs: &mut dyn Observer) -> Option<Us> {
        let cost = self.cfg.cost;
        let batch = self.cfg.coupled_batch;
        let more_arrivals = self.arrivals_pending > 0;
        let slow = self.plan.as_ref().map(|p| p.slowdown(c, now)).unwrap_or(1.0);
        let ci = self.pool.coupled_mut(c)?;
        let st =
            ci.begin_iteration(&self.core.requests, &cost, batch, batch as u32, more_arrivals, now)?;
        let dur = scale_dur(st.dur, slow);
        self.core.metrics.busy_us[c] += dur;
        if st.prefill_tokens > 0 {
            obs.on_chunk(now, c, st.prefill_tokens, 0, dur);
        }
        if st.batch > 0 {
            obs.on_decode_iter(now, c, st.batch, st.kv_tokens, dur);
        }
        // the waiting-line batch admitted into this iteration opens each
        // request's prefill span (coupled prompts prefill whole, one shot)
        if let Some(ci) = self.pool.coupled_mut(c) {
            for k in 0..ci.pending_prefilled.len() {
                let slot = ci.pending_prefilled[k];
                obs.on_prefill_start(now, c, self.core.requests[slot as usize].id);
            }
        }
        Some(now + dur)
    }

    fn try_start_coupled(&mut self, c: usize, obs: &mut dyn Observer) {
        let now = self.core.now();
        if let Some(end) = self.start_coupled_iteration(c, now, obs) {
            let epoch = self.pool.epoch(c);
            self.core.queue.schedule_at(end, Event::CoupledIterDone { instance: c, epoch });
        }
    }

    /// Close the mixed iteration that just ended on coupled instance `c`
    /// at virtual time `now`: stamp first tokens, finish single-token
    /// prompts and completed decodes, hand the buffers back for reuse.
    fn close_coupled_iteration(&mut self, c: usize, now: Us, obs: &mut dyn Observer) {
        let Some(ci) = self.pool.coupled_mut(c) else { return };
        let (mut prefilled, mut done) = ci.end_iteration(now);
        for slot in prefilled.drain(..) {
            self.core.hot[slot as usize].first_token = now;
            obs.on_prefill_finish(now, c, self.core.requests[slot as usize].id);
            // single-token requests finish at prefill
            if self.core.requests[slot as usize].decode_len <= 1 {
                if let Some(ci) = self.pool.coupled_mut(c) {
                    ci.drop_running(slot);
                }
                self.core.finish(slot, now, obs);
            } else {
                // the rest stay resident and decode in place
                obs.on_decode_enter(now, c, self.core.requests[slot as usize].id);
            }
        }
        for slot in done.drain(..) {
            self.core.finish(slot, now, obs);
        }
        if let Some(ci) = self.pool.coupled_mut(c) {
            ci.return_bufs(prefilled, done);
        }
    }

    /// Coupled iteration-complete handler: the same [`macro_chain`]
    /// scaffold as [`Cluster::on_decode_done`]. The waiting line only
    /// grows on arrival events and `arrivals_pending` only moves with
    /// them, so inside the strictly-before-external window successive
    /// mixed iterations are a function of instance-local state.
    fn on_coupled_done(&mut self, c: usize, epoch: u32, obs: &mut dyn Observer) {
        if self.pool.epoch(c) != epoch {
            return; // crashed mid-iteration (see on_decode_done)
        }
        let macro_on = self.cfg.macro_step;
        macro_chain(
            self,
            macro_on,
            obs,
            |s, now, obs| s.close_coupled_iteration(c, now, obs),
            |s, now, obs| s.start_coupled_iteration(c, now, obs),
            |s, end| {
                let epoch = s.pool.epoch(c);
                s.core.queue.schedule_at(end, Event::CoupledIterDone { instance: c, epoch })
            },
        );
    }

    // ----------------------------------------------------------- monitor

    fn refresh_broadcast(&mut self) {
        // reuse both buffers — this runs every monitor tick
        for e in self.since_tick.iter_mut() {
            *e = (0, 0, 0);
        }
        self.broadcast.clear();
        for (i, inst) in self.pool.iter().enumerate() {
            if !inst.accepts_work() {
                continue; // draining decodes take no new dispatches
            }
            if let InstanceState::Decode(di) = &inst.state {
                let (h, l) = di.sched.heavy_light();
                self.broadcast.push(DecodeLoad {
                    instance: i,
                    free_kv_tokens: di.kv.free_tokens(),
                    n_heavy: h,
                    n_light: l,
                    queue_len: di.sched.queue_len(),
                });
            }
        }
    }

    fn on_monitor_tick(&mut self, obs: &mut dyn Observer) {
        self.refresh_broadcast();
        obs.on_monitor(self.core.now(), &self.broadcast);
        self.complete_drains(obs);
        // Queued work per role, computed once per tick for both the flip
        // and the scale policies.
        let (prefill_pressure, decode_pressure) = self.role_pressures();
        self.maybe_flip(prefill_pressure, decode_pressure, obs);
        self.maybe_scale(prefill_pressure, decode_pressure, obs);
        // Retry any dispatches parked while no decode instance existed.
        // Under a fault plan, a park with no live decode instance and no
        // restart pending may never heal on its own — burn retry budget
        // (the re-queue path re-prefills once capacity returns via the
        // elastic pool, or fails the request bounded).
        // (swap with the scratch buffer, not `mem::take`, so *both*
        // vectors keep their capacity across ticks — zero-alloc steady
        // state)
        std::mem::swap(&mut self.pending_dispatch, &mut self.scratch.dispatch);
        for k in 0..self.scratch.dispatch.len() {
            let slot = self.scratch.dispatch[k];
            if !self.dispatch_request(slot, obs) {
                if self.plan.is_some()
                    && !self.pool.any_restart_pending()
                    && !self.has_live_decode()
                {
                    self.requeue_lost(slot, false, obs);
                } else {
                    self.pending_dispatch.push(slot);
                }
            }
        }
        self.scratch.dispatch.clear();
        if self.core.outstanding > 0 {
            self.core.queue.schedule_in(self.cfg.monitor_interval_us, Event::MonitorTick);
        }
    }

    /// Any instance currently serving decode and accepting work.
    fn has_live_decode(&self) -> bool {
        (0..self.pool.len()).any(|i| {
            self.pool.accepts_work(i) && matches!(self.pool.state(i), InstanceState::Decode(_))
        })
    }

    /// Queued work per role across instances accepting new work. Draining
    /// instances serve out their own backlog and are excluded — their
    /// work neither justifies a flip toward the role nor a scale-up.
    fn role_pressures(&self) -> (u64, u64) {
        let (mut prefill, mut decode) = (0u64, 0u64);
        for inst in self.pool.iter() {
            if !inst.accepts_work() {
                continue;
            }
            match &inst.state {
                InstanceState::Prefill(p) => prefill += p.load(),
                InstanceState::Decode(d) => decode += d.sched.total_jobs() as u64,
                _ => {}
            }
        }
        // Prefilled requests parked for want of a decode instance are
        // decode-side backlog too: after a decode crash they are what the
        // elastic pool must grow for. Plan-gated — fault-free runs keep
        // the legacy pressure signal bit for bit.
        if self.plan.is_some() {
            decode += self.pending_dispatch.len() as u64;
        }
        (prefill, decode)
    }

    /// Finish every drain whose last work item has left: retire the slot,
    /// or launch the role switch it was draining toward.
    fn complete_drains(&mut self, obs: &mut dyn Observer) {
        let now = self.core.now();
        for i in 0..self.pool.len() {
            let Some(target) = self.pool.get(i).drain_to else { continue };
            if !self.pool.is_drained(i) {
                continue;
            }
            // role teardown/flip allocates (fresh role state) — cold path
            let _cold = crate::util::cold_section();
            let role = self.pool.state(i).role().expect("draining instances serve a role");
            match target {
                DrainTarget::Retire => {
                    self.swapped_graveyard += self.pool.retire(i);
                    self.pool.get_mut(i).retired_at = Some(now);
                    if role == Role::Prefill {
                        self.least_prefill_dirty = true;
                        self.cache_invalidate(i);
                    }
                    self.core.metrics.scale_downs += 1;
                    obs.on_scale(now, i, role, false);
                }
                DrainTarget::Flip(to) => {
                    let fc = self.cfg.flip.unwrap_or_default();
                    let dur = self.rng.range(fc.flip_min_us, fc.flip_max_us + 1);
                    self.swapped_graveyard += self.pool.begin_flip(i, to);
                    if role == Role::Prefill {
                        self.least_prefill_dirty = true;
                        self.cache_invalidate(i);
                    }
                    self.core.metrics.flips += 1;
                    self.core.queue.schedule_in(dur, Event::FlipDone { instance: i });
                    obs.on_flip(now, i, to, dur);
                }
            }
        }
    }

    // -------------------------------------------------------------- flip

    /// The §3.5 idleness policy over the pre-computed role pressures
    /// (any queued work on the other role — the paper flips on the
    /// instance's own idleness; requiring the other role to actually
    /// have work avoids useless role churn).
    fn maybe_flip(&mut self, prefill_pressure: u64, decode_pressure: u64, obs: &mut dyn Observer) {
        let Some(flip) = self.cfg.flip else { return };
        let now = self.core.now();
        let n_prefill = self.pool.n_active(Role::Prefill);
        let n_decode = self.pool.n_active(Role::Decode);

        for i in 0..self.pool.len() {
            if !self.pool.accepts_work(i) {
                continue; // draining instances follow their own target
            }
            let to = match self.pool.state(i) {
                InstanceState::Prefill(p)
                    if !p.busy
                        && p.sched.is_empty()
                        && !p.chunker.has_work()
                        && now.saturating_sub(p.last_active) >= flip.idle_us
                        && n_prefill > flip.min_per_role
                        && decode_pressure > 0 =>
                {
                    Role::Decode
                }
                InstanceState::Decode(d)
                    if !d.busy
                        && d.sched.total_jobs() == 0
                        && now.saturating_sub(d.last_active) >= flip.idle_us
                        && n_decode > flip.min_per_role
                        && prefill_pressure > 0 =>
                {
                    Role::Prefill
                }
                _ => continue,
            };
            // flips allocate (role teardown, flip event) — cold path
            let _cold = crate::util::cold_section();
            // drained already (idle): flip is just the role switch
            let dur = self.rng.range(flip.flip_min_us, flip.flip_max_us + 1);
            self.swapped_graveyard += self.pool.begin_flip(i, to);
            if to == Role::Decode {
                self.least_prefill_dirty = true; // a prefill instance left
                self.cache_invalidate(i); // its cached KV leaves with it
            }
            self.core.metrics.flips += 1;
            self.core.queue.schedule_in(dur, Event::FlipDone { instance: i });
            obs.on_flip(now, i, to, dur);
            return; // at most one flip per tick
        }
    }

    fn on_flip_done(&mut self, i: usize) {
        // fresh role state construction allocates — cold path
        let _cold = crate::util::cold_section();
        let to = match self.pool.state(i) {
            InstanceState::Flipping { to } => *to,
            _ => return,
        };
        let state = match to {
            Role::Prefill => InstanceState::Prefill(new_prefill_inst(&self.cfg, self.core.now())),
            Role::Decode => InstanceState::Decode(new_decode_inst(&self.cfg)),
            Role::Coupled => unreachable!("flips never target the coupled role"),
        };
        self.pool.finish_flip(i, state);
        self.least_prefill_dirty = true;
        self.refresh_broadcast();
    }

    // ----------------------------------------------------------- elastic

    /// Grow a slot for a freshly added instance across every
    /// instance-indexed structure, stamping its birth time for the
    /// alive/utilization accounting.
    fn add_instance(&mut self, state: InstanceState) -> usize {
        // pool growth allocates across every instance-indexed structure
        let _cold = crate::util::cold_section();
        let i = self.pool.push(state);
        self.pool.get_mut(i).born = self.core.now();
        self.core.grow_instances(self.pool.len());
        self.since_tick.push((0, 0, 0));
        if let Some(pc) = self.cfg.prefix_cache {
            self.prefix_caches.push(PrefixCache::new(pc));
        }
        i
    }

    /// The elastic pool policy: at most one new decision per tick — grow
    /// the pressured role, or start draining an idle instance (drain
    /// completions are handled by [`Cluster::complete_drains`]). The
    /// pressures come pre-computed from the monitor tick and exclude
    /// draining instances' own backlogs. Coupled instances never scale —
    /// the hybrid comparison keeps that fleet fixed.
    fn maybe_scale(&mut self, prefill_backlog: u64, decode_backlog: u64, obs: &mut dyn Observer) {
        let Some(el) = self.cfg.elastic else { return };
        let now = self.core.now();
        // 1. Scale up the role whose backlog per active instance runs hot.
        if self.pool.n_live() < el.max_instances {
            let np = self.pool.n_active(Role::Prefill).max(1) as u64;
            if prefill_backlog > el.prefill_up_tokens * np {
                let _cold = crate::util::cold_section();
                let state = InstanceState::Prefill(new_prefill_inst(&self.cfg, now));
                let i = self.add_instance(state);
                self.least_prefill_dirty = true;
                self.core.metrics.scale_ups += 1;
                obs.on_scale(now, i, Role::Prefill, true);
                return;
            }
            let nd = self.pool.n_active(Role::Decode).max(1) as u64;
            if decode_backlog > el.decode_up_jobs * nd {
                let _cold = crate::util::cold_section();
                let state = InstanceState::Decode(new_decode_inst(&self.cfg));
                let i = self.add_instance(state);
                self.core.metrics.scale_ups += 1;
                self.refresh_broadcast(); // dispatches must see it now
                obs.on_scale(now, i, Role::Decode, true);
                return;
            }
        }
        // 2. Drain one instance that has idled past the threshold.
        for i in 0..self.pool.len() {
            if !self.pool.accepts_work(i) {
                continue;
            }
            let Some(r) = self.pool.state(i).as_role() else { continue };
            let role = r.role();
            if role == Role::Coupled {
                continue;
            }
            if r.drained()
                && now.saturating_sub(r.last_active()) >= el.down_idle_us
                && self.pool.n_active(role) > el.min_per_role
            {
                let _cold = crate::util::cold_section();
                self.pool.begin_drain(i, DrainTarget::Retire);
                if role == Role::Prefill {
                    self.least_prefill_dirty = true;
                } else {
                    self.refresh_broadcast(); // stop dispatching to it
                }
                return;
            }
        }
    }

    // ------------------------------------------------------------- fault

    /// Deliver fault-plan event `k`: resolve its target against the live
    /// set, open link/straggler windows, or crash an instance.
    fn on_fault_event(&mut self, k: usize, obs: &mut dyn Observer) {
        // fault delivery allocates freely (harvests, target resolution)
        let _cold = crate::util::cold_section();
        let now = self.core.now();
        let live = self.pool.live_roles();
        let inj = match self.plan.as_mut() {
            Some(p) => p.fire(k, now, &live),
            None => return,
        };
        match inj {
            Injection::Skipped => {}
            Injection::Crash { instance, restart_at } => {
                self.core.metrics.faults_injected += 1;
                self.crash_instance(instance, restart_at, obs);
                if let Some(at) = restart_at {
                    self.core.queue.schedule_at(at, Event::Restart { instance });
                }
            }
            Injection::Link { outage, .. } => {
                self.core.metrics.faults_injected += 1;
                obs.on_fault(now, if outage { "link_out" } else { "link_degrade" }, None);
            }
            Injection::Straggle { instance, .. } => {
                self.core.metrics.faults_injected += 1;
                obs.on_fault(now, "straggler", Some(instance));
            }
        }
    }

    /// Abrupt instance failure: harvest every request whose state dies
    /// with the incarnation, tear the role state down (epoch bump makes
    /// in-flight completions inert), rescue its swap tallies into the
    /// graveyard, and re-queue or fail the harvested requests.
    fn crash_instance(&mut self, i: usize, until: Option<Us>, obs: &mut dyn Observer) {
        // crash harvest + re-queues allocate — cold path by definition
        let _cold = crate::util::cold_section();
        let now = self.core.now();
        // harvest before the role state is destroyed
        let mut lost = match self.pool.state_mut(i) {
            InstanceState::Prefill(p) => p.harvest_crashed(),
            InstanceState::Decode(d) => d.harvest_crashed(),
            InstanceState::Coupled(c) => c.harvest_crashed(),
            _ => Vec::new(),
        };
        let Some((role, swapped)) = self.pool.crash(i, until) else { return };
        self.swapped_graveyard += swapped;
        // every block cached on the dead incarnation died with its KV
        self.cache_invalidate(i);
        if until.is_none() {
            // permanent loss: close the alive span like a retirement
            self.pool.get_mut(i).retired_at = Some(now);
        }
        if role == Role::Prefill {
            self.least_prefill_dirty = true;
        }
        self.refresh_broadcast();
        // Parked dispatches whose KV lived on the crashed prefill lost
        // their payload — they re-prefill. Others stay parked.
        let parked = std::mem::take(&mut self.pending_dispatch);
        for slot in parked {
            let from_crashed = self.core.hot[slot as usize]
                .prefilled_by
                .map(|(src, _)| src == i)
                .unwrap_or(false);
            if from_crashed {
                lost.push(slot);
            } else {
                self.pending_dispatch.push(slot);
            }
        }
        obs.on_fault(now, "crash", Some(i));
        for slot in lost {
            self.requeue_lost(slot, false, obs);
        }
        self.check_degraded(obs);
    }

    /// Re-queue a request lost to a fault: charge a retry against the
    /// plan's budget and re-enter the arrival router after exponential
    /// backoff, or fail the request once the budget is spent. `pending`
    /// says whether the slot still counts in `arrivals_pending` (it never
    /// reached a local scheduler) — the bookkeeping differs because the
    /// retry path re-charges `note_enqueued` when it lands.
    fn requeue_lost(&mut self, slot: ReqId, pending: bool, obs: &mut dyn Observer) {
        // fault-recovery bookkeeping — cold path (plan-gated)
        let _cold = crate::util::cold_section();
        // any residual prefill residency or cache pin is stale now
        // (epoch-guarded: no-ops when the holding instance crashed)
        self.cache_release_pin(slot);
        self.release_prefill_resident(slot);
        let now = self.core.now();
        let n = self.core.note_lost(slot, now);
        let (retry_max, backoff) = match self.plan.as_ref() {
            Some(p) => (p.retry_max(), p.backoff_us(n)),
            None => return, // unreachable: fault paths require a plan
        };
        if n > retry_max {
            if pending {
                // leaves the global queue without ever enqueuing —
                // unblock coupled partial batches like a shed
                self.note_enqueued(obs);
            }
            self.core.fail(slot, obs);
            return;
        }
        if !pending {
            // the retry re-enters the arrival router, which charges
            // note_enqueued again when the request lands
            self.arrivals_pending += 1;
        }
        obs.on_backoff(now, self.core.requests[slot as usize].id, now + backoff);
        self.core.queue.schedule_in(backoff, Event::Retry(slot));
        obs.on_recovery(now, "requeue", None);
    }

    /// A crashed slot's downtime elapsed: restart it with a fresh (empty)
    /// role state on the post-crash epoch.
    fn on_restart(&mut self, i: usize, obs: &mut dyn Observer) {
        // fresh role state construction allocates — cold path
        let _cold = crate::util::cold_section();
        let Some(role) = self.pool.dead_role(i) else { return };
        let now = self.core.now();
        let state = match role {
            Role::Prefill => InstanceState::Prefill(new_prefill_inst(&self.cfg, now)),
            Role::Decode => InstanceState::Decode(new_decode_inst(&self.cfg)),
            Role::Coupled => InstanceState::Coupled(new_coupled_inst(&self.cfg)),
        };
        if !self.pool.install_restarted(i, state) {
            return;
        }
        self.least_prefill_dirty = true;
        self.refresh_broadcast();
        obs.on_recovery(now, "restart", Some(i));
        self.check_degraded(obs);
        // parked dispatches may have a target again
        for slot in std::mem::take(&mut self.pending_dispatch) {
            if !self.dispatch_request(slot, obs) {
                self.pending_dispatch.push(slot);
            }
        }
    }

    /// Re-evaluate degraded mode against the plan's capacity watermark.
    /// Only crash/restart events move live capacity, so this is called
    /// exactly there — never on the hot path.
    fn check_degraded(&mut self, obs: &mut dyn Observer) {
        let Some(watermark) = self.plan.as_ref().map(|p| p.watermark()) else { return };
        let now = self.core.now();
        let live = self.pool.live_roles().len();
        let degraded = (live as f64) < watermark * self.base_capacity as f64;
        match (degraded, self.degraded_since) {
            (true, None) => {
                self.degraded_since = Some(now);
                obs.on_fault(now, "degraded", None);
            }
            (false, Some(since)) => {
                self.core.metrics.degraded_us += now.saturating_sub(since);
                self.degraded_since = None;
                obs.on_recovery(now, "capacity_restored", None);
            }
            _ => {}
        }
    }
}

impl EngineHost for Cluster {
    fn core_mut(&mut self) -> &mut EngineCore {
        &mut self.core
    }

    fn driver_name(&self) -> &'static str {
        "cluster"
    }

    fn begin(&mut self, _obs: &mut dyn Observer) {
        // arrivals stream in lazily: the count of not-yet-enqueued
        // requests starts at the source's total, not the arena size
        self.arrivals_pending = self.core.total_expected;
        self.base_capacity = self.pool.live_roles().len();
        if let Some(plan) = self.plan.as_ref() {
            // the chaos schedule rides the normal event queue — fault
            // events bound macro chains like any other external event —
            // seeded in one batched admission (sorted per bucket once)
            self.core
                .queue
                .push_batch(plan.events().iter().enumerate().map(|(k, ev)| (ev.at, Event::Fault(k))));
        }
        self.refresh_broadcast();
        self.core.queue.schedule_in(self.cfg.monitor_interval_us, Event::MonitorTick);
    }

    fn handle(&mut self, ev: Event, obs: &mut dyn Observer) {
        match ev {
            Event::Arrival(slot) => self.on_arrival(slot, obs),
            Event::PredictDone { instance, epoch, req } => {
                self.on_predict_done(instance, epoch, req, obs)
            }
            Event::PrefillIterDone { instance, epoch } => {
                self.on_prefill_done(instance, epoch, obs)
            }
            Event::TransferDone { instance, epoch, req } => {
                self.on_transfer_done(instance, epoch, req, obs)
            }
            Event::DecodeIterDone { instance, epoch } => self.on_decode_done(instance, epoch, obs),
            Event::CoupledIterDone { instance, epoch } => {
                self.on_coupled_done(instance, epoch, obs)
            }
            Event::MonitorTick => self.on_monitor_tick(obs),
            Event::FlipDone { instance } => self.on_flip_done(instance),
            Event::Fault(k) => self.on_fault_event(k, obs),
            Event::Restart { instance } => self.on_restart(instance, obs),
            // a retry re-enters the arrival router (the arrival hook
            // fired long ago — note_arrival is idempotent)
            Event::Retry(slot) => self.on_arrival(slot, obs),
        }
    }

    fn end(&mut self, _obs: &mut dyn Observer) {
        // Per-slot alive spans: birth → retirement (or run end). Static
        // pools get full-run spans, elastic additions and retirements get
        // exactly the window they existed — the denominator behind
        // utilization() and the paper's resource-usage fairness metric.
        let now = self.core.now();
        for (i, inst) in self.pool.iter().enumerate() {
            let until = inst.retired_at.unwrap_or(now);
            self.core.metrics.alive_us[i] = until.saturating_sub(inst.born);
        }
        let mut swapped = self.swapped_graveyard;
        for inst in self.pool.iter() {
            if let Some(kv) = inst.state.as_role().and_then(|r| r.kv()) {
                swapped += kv.swapped_out_tokens;
            }
        }
        self.core.metrics.swapped_tokens += swapped;
        // a run ending inside degraded mode still reports the open span
        if let Some(since) = self.degraded_since.take() {
            self.core.metrics.degraded_us += now.saturating_sub(since);
        }
        // fold the per-instance prefix-cache ledgers into the run totals
        for c in &self.prefix_caches {
            self.core.metrics.cache_hits += c.stats.hits;
            self.core.metrics.cache_misses += c.stats.misses;
            self.core.metrics.prefill_tokens_saved += c.stats.saved_tokens;
            self.core.metrics.cache_evictions += c.stats.evicted_blocks;
        }
    }
}

fn new_prefill_inst(cfg: &ClusterConfig, now: Us) -> PrefillInst {
    let mut p =
        PrefillInst::new(cfg.prefill_policy, cfg.sched_batch, cfg.chunk_size, cfg.srtf_chunking, now);
    // the SLO policy sorts by (tier, deadline) from the class table;
    // other policies ignore it (tiny vec, set unconditionally)
    p.sched.set_class_table(cfg.slo.prefill_table());
    p
}

fn new_decode_inst(cfg: &ClusterConfig) -> DecodeInst {
    let pages = (cfg.cost.kv_capacity_tokens() / 16) as u32;
    DecodeInst::new(cfg.decode_policy, cfg.granularity, cfg.max_batch, pages)
}

fn new_coupled_inst(cfg: &ClusterConfig) -> CoupledInst {
    let pages = (cfg.cost.kv_capacity_tokens() / 16) as u32;
    CoupledInst::new(pages)
}

/// Convenience: run a trace through the cluster driver (the same
/// `api::Driver` the scenario registry resolves for `"tetri"`), with no
/// observer attached.
pub fn run_cluster(cfg: ClusterConfig, trace: Vec<Request>) -> RunMetrics {
    use crate::api::Driver as _;
    crate::api::ClusterDriver::from_config(cfg)
        .run(&trace, &mut NullObserver)
        .metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ElasticConfig;
    use crate::workload::{WorkloadGen, WorkloadKind};

    fn small_cfg() -> ClusterConfig {
        ClusterConfig { n_prefill: 1, n_decode: 2, flip: None, ..Default::default() }
    }

    #[test]
    fn completes_every_request() {
        let mut gen = WorkloadGen::new(1);
        let trace = gen.trace(WorkloadKind::Mixed, 64, 20.0, 0);
        let m = run_cluster(small_cfg(), trace);
        assert_eq!(m.records.len(), 64);
        assert!(m.events > 64, "every request takes several events");
        for r in &m.records {
            assert!(r.first_token >= r.arrival, "TTFT before arrival");
            assert!(r.finished >= r.first_token, "JCT before TTFT");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut gen = WorkloadGen::new(3);
            run_cluster(small_cfg(), gen.trace(WorkloadKind::Mixed, 32, 50.0, 0))
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.makespan_us, b.makespan_us);
        assert_eq!(a.events, b.events);
        assert!((a.jct_summary().mean - b.jct_summary().mean).abs() < 1e-9);
    }

    #[test]
    fn ttft_beats_jct_ordering_and_busy_time_positive() {
        let mut gen = WorkloadGen::new(5);
        let m = run_cluster(small_cfg(), gen.trace(WorkloadKind::Lpld, 32, 0.0, 0));
        assert!(m.resource_seconds() > 0.0);
        assert!(m.makespan_us > 0);
        assert!(m.ttft_summary().mean <= m.jct_summary().mean);
    }

    #[test]
    fn nvlink_transfers_beat_roce_on_ttft_to_first_decode() {
        let mut gen = WorkloadGen::new(7);
        let trace = gen.trace(WorkloadKind::Lphd, 48, 0.0, 0);
        let roce = run_cluster(ClusterConfig { flip: None, ..ClusterConfig::ts_roce(1, 2) }, trace.clone());
        let nv = run_cluster(ClusterConfig { flip: None, ..ClusterConfig::ts_nvlink(1, 2) }, trace);
        // transfer is off the TTFT path but on the JCT path
        assert!(nv.jct_summary().mean <= roce.jct_summary().mean * 1.01);
    }

    #[test]
    fn flip_activates_under_idle_prefill() {
        let mut gen = WorkloadGen::new(9);
        // decode-heavy workload with a tiny flip threshold: the second
        // prefill instance should flip to decode.
        let cfg = ClusterConfig {
            n_prefill: 2,
            n_decode: 1,
            flip: Some(crate::coordinator::FlipConfig {
                idle_us: 1_000_000,
                ..Default::default()
            }),
            ..Default::default()
        };
        let trace = gen.trace(WorkloadKind::Lphd, 96, 0.0, 0);
        let m = run_cluster(cfg, trace);
        assert_eq!(m.records.len(), 96);
        assert!(m.flips >= 1, "expected at least one prefill→decode flip");
    }

    #[test]
    fn more_decode_instances_reduce_jct_for_heavy_decode() {
        let mut gen = WorkloadGen::new(11);
        let trace = gen.trace(WorkloadKind::Lphd, 128, 0.0, 0);
        let one = run_cluster(ClusterConfig { n_decode: 1, ..small_cfg() }, trace.clone());
        let four = run_cluster(ClusterConfig { n_decode: 4, ..small_cfg() }, trace);
        assert!(
            four.jct_summary().mean < one.jct_summary().mean,
            "scaling decode must help heavy-decode workloads"
        );
    }

    #[test]
    fn records_report_original_request_ids() {
        // Arena slots are internal: records must carry the trace's ids
        // even when they are sparse.
        let mut gen = WorkloadGen::new(13);
        let trace: Vec<Request> = gen
            .trace(WorkloadKind::Lpld, 16, 0.0, 0)
            .into_iter()
            .map(|mut r| {
                r.id += 5_000;
                r
            })
            .collect();
        let m = run_cluster(small_cfg(), trace);
        assert_eq!(m.records.len(), 16);
        for r in &m.records {
            assert!(r.id >= 5_000, "record lost its original id: {}", r.id);
        }
    }

    #[test]
    fn multi_prefill_release_targets_the_prefilling_instance() {
        // Two prefill instances under a standing backlog: the residency
        // counters must drain back to a sane state (the old "subtract
        // wherever it fits" release corrupted them), so the run completes
        // and each instance keeps doing work.
        let mut gen = WorkloadGen::new(17);
        let trace = gen.trace(WorkloadKind::Hpld, 96, 0.0, 0);
        let m = run_cluster(
            ClusterConfig { flip: None, ..ClusterConfig::ts_roce(2, 2) },
            trace,
        );
        assert_eq!(m.records.len(), 96);
        assert!(m.busy_us[0] > 0 && m.busy_us[1] > 0, "both prefill instances must serve");
    }

    #[test]
    fn hybrid_serves_through_both_architectures() {
        // One disaggregated pair + one coupled instance in the same
        // cluster: every request completes, and both entry points did
        // real work (the router balances token-denominated loads).
        let mut gen = WorkloadGen::new(19);
        let trace = gen.trace(WorkloadKind::Mixed, 96, 24.0, 0);
        let cfg = ClusterConfig { n_prefill: 1, n_decode: 1, n_coupled: 1, flip: None, ..Default::default() };
        let m = run_cluster(cfg, trace);
        assert_eq!(m.records.len(), 96);
        assert_eq!(m.busy_us.len(), 3);
        assert!(m.busy_us[0] > 0, "disaggregated prefill must serve");
        assert!(m.busy_us[2] > 0, "coupled instance must serve");
    }

    #[test]
    fn admission_gate_sheds_rate_limited_class_and_conserves() {
        use crate::slo::{ClassSpec, SloConfig};
        // Two classes, everything stamped class 1 via weights (class 0
        // weight 0): class 1 is hard rate-limited, so a 64-request batch
        // burst at t=0 admits exactly `burst` and sheds the rest.
        let mut gen = WorkloadGen::new(31);
        gen.set_classes(vec![0.0, 1.0]);
        let trace = gen.trace(WorkloadKind::Lpld, 64, 0.0, 0);
        assert!(trace.iter().all(|r| r.class == 1));
        let slo = SloConfig {
            classes: vec![
                ClassSpec::default().to_def(),
                ClassSpec {
                    name: "batch".into(),
                    tier: 2,
                    rate_limit: Some(1.0),
                    burst: Some(5.0),
                    ..Default::default()
                }
                .to_def(),
            ],
            admission: true,
        };
        let m = run_cluster(ClusterConfig { slo, ..small_cfg() }, trace);
        // batch arrival at t=0: exactly the burst is admitted
        assert_eq!(m.shed, 59, "64 arrivals minus burst 5 must shed");
        assert_eq!(m.records.len(), 5);
        assert_eq!(m.per_class[1].shed, 59);
        assert_eq!(m.per_class[1].finished, 5);
        assert_eq!(m.finished + m.shed, 64, "sheds + finishes conserve arrivals");
    }

    #[test]
    fn slo_policy_prioritizes_tier0_ttft_under_backlog() {
        use crate::slo::{ClassSpec, SloConfig};
        // A standing backlog where half the requests are tier 0 with a
        // TTFT deadline and half are tier 2 without: SLO-EDF must give
        // tier 0 a lower mean TTFT than tier 2 on the same trace.
        let mk_trace = || {
            let mut gen = WorkloadGen::new(37);
            gen.set_classes(vec![0.5, 0.5]);
            gen.trace(WorkloadKind::Mixed, 96, 0.0, 0)
        };
        let slo = SloConfig {
            classes: vec![
                ClassSpec { name: "chat".into(), ttft_ms: Some(500.0), ..Default::default() }
                    .to_def(),
                ClassSpec { name: "batch".into(), tier: 2, ..Default::default() }.to_def(),
            ],
            admission: false,
        };
        let cfg = ClusterConfig {
            prefill_policy: crate::prefill::PrefillPolicy::Slo,
            sched_batch: 96,
            slo,
            ..small_cfg()
        };
        let m = run_cluster(cfg, mk_trace());
        assert_eq!(m.records.len(), 96);
        let mean = |class: u8| {
            let xs: Vec<f64> = m
                .records
                .iter()
                .filter(|r| r.class == class)
                .map(|r| r.ttft() as f64)
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        assert!(
            mean(0) < mean(1),
            "tier 0 must prefill ahead of tier 2: {} vs {}",
            mean(0),
            mean(1)
        );
    }

    #[test]
    fn elastic_scales_up_under_backlog() {
        // A batch burst against a single prefill/decode pair with tiny
        // thresholds: the pool must grow, and every request completes.
        let mut gen = WorkloadGen::new(21);
        let trace = gen.trace(WorkloadKind::Hphd, 96, 0.0, 0);
        let cfg = ClusterConfig {
            n_prefill: 1,
            n_decode: 1,
            flip: None,
            elastic: Some(ElasticConfig {
                max_instances: 6,
                prefill_up_tokens: 1024,
                decode_up_jobs: 8,
                ..Default::default()
            }),
            ..Default::default()
        };
        let m = run_cluster(cfg, trace);
        assert_eq!(m.records.len(), 96);
        assert!(m.scale_ups >= 1, "backlog must grow the pool");
        assert!(m.busy_us.len() > 2, "added instances get metric slots");
    }

    #[test]
    fn elastic_drains_and_retires_idle_instances() {
        // A burst, then a long quiet gap before a single straggler: the
        // instances added for the burst idle past the threshold and must
        // drain + retire, never losing a request.
        let mut gen = WorkloadGen::new(23);
        let mut trace = gen.trace(WorkloadKind::Hphd, 64, 0.0, 0);
        let mut straggler = gen.trace(WorkloadKind::Lpld, 1, 0.0, 0);
        straggler[0].arrival = 60_000_000; // a long quiet gap
        trace.extend(straggler);
        let cfg = ClusterConfig {
            n_prefill: 1,
            n_decode: 1,
            flip: None,
            elastic: Some(ElasticConfig {
                max_instances: 6,
                prefill_up_tokens: 1024,
                decode_up_jobs: 8,
                down_idle_us: 1_000_000,
                min_per_role: 1,
            }),
            ..Default::default()
        };
        let m = run_cluster(cfg, trace);
        assert_eq!(m.records.len(), 65, "no request may be lost across scale events");
        assert!(m.scale_ups >= 1, "the burst must grow the pool");
        assert!(m.scale_downs >= 1, "the quiet gap must shrink it again");
    }

    fn fault_cfg(events: Vec<crate::fault::FaultEvent>) -> crate::fault::FaultConfig {
        crate::fault::FaultConfig { events, retry_max: 4, backoff_us: 25_000, watermark: 0.5 }
    }

    #[test]
    fn empty_fault_plan_is_bit_identical_to_no_plan() {
        // `faults` present but with no events must not perturb a single
        // draw or duration — the acceptance bar for fault-free parity.
        let mk_trace = || {
            let mut gen = WorkloadGen::new(41);
            gen.trace(WorkloadKind::Mixed, 48, 30.0, 0)
        };
        let a = run_cluster(small_cfg(), mk_trace());
        let b = run_cluster(
            ClusterConfig { fault: Some(fault_cfg(Vec::new())), ..small_cfg() },
            mk_trace(),
        );
        assert_eq!(a.makespan_us, b.makespan_us);
        assert_eq!(a.events, b.events);
        assert_eq!(a.records.len(), b.records.len());
        for (ra, rb) in a.records.iter().zip(b.records.iter()) {
            assert_eq!((ra.first_token, ra.finished), (rb.first_token, rb.finished));
            assert_eq!(rb.retries, 0);
            assert!(!rb.recovered);
        }
    }

    #[test]
    fn decode_crash_with_restart_recovers_and_conserves() {
        use crate::fault::{FaultEvent, FaultKind};
        // Batch burst over two decode instances; one dies mid-backlog and
        // restarts 300 ms later. Its jobs re-enter prefill with backoff;
        // everything must still complete and conservation must hold.
        let mut gen = WorkloadGen::new(43);
        let trace = gen.trace(WorkloadKind::Hphd, 64, 0.0, 0);
        let ev = FaultEvent {
            at: 150_000,
            kind: FaultKind::Restart,
            instance: Some(2), // second decode in [prefill, decode, decode]
            down: 300_000,
            factor: 1.0,
        };
        let m = run_cluster(
            ClusterConfig { fault: Some(fault_cfg(vec![ev])), ..small_cfg() },
            trace,
        );
        assert_eq!(m.faults_injected, 1);
        assert_eq!(
            m.finished + m.shed + m.failed,
            64,
            "conservation: every arrival is finished, shed, or failed"
        );
        assert_eq!(m.failed, 0, "a surviving decode + a restart must rescue every request");
        assert!(m.recovered >= 1, "the crashed instance's jobs must re-enter service");
        for r in &m.records {
            assert!(r.retries <= 4, "retry budget exceeded: {}", r.retries);
            assert!(r.finished >= r.first_token);
        }
    }

    #[test]
    fn permanent_crash_of_only_decode_fails_bounded() {
        use crate::fault::{FaultEvent, FaultKind};
        // The single decode instance dies for good, no flip, no elastic:
        // in-flight and later requests burn their retry budget and fail —
        // the run terminates and conservation still holds.
        let mut gen = WorkloadGen::new(47);
        let trace = gen.trace(WorkloadKind::Lphd, 32, 0.0, 0);
        let ev = FaultEvent {
            at: 100_000,
            kind: FaultKind::Crash,
            instance: Some(1),
            down: 0,
            factor: 1.0,
        };
        let cfg = ClusterConfig {
            n_prefill: 1,
            n_decode: 1,
            flip: None,
            // watermark 0.8 over a base of 2: one loss (1 < 1.6) degrades
            fault: Some(crate::fault::FaultConfig { watermark: 0.8, ..fault_cfg(vec![ev]) }),
            ..Default::default()
        };
        let m = run_cluster(cfg, trace);
        assert_eq!(m.finished + m.shed + m.failed, 32);
        assert!(m.failed >= 1, "requests with no decode capacity must fail, not spin");
        assert!(m.degraded_us > 0, "losing half the fleet crosses the watermark");
    }

    #[test]
    fn elastic_pool_replaces_a_permanently_dead_decode() {
        use crate::fault::{FaultEvent, FaultKind};
        // Same permanent decode crash, but with the elastic pool on: the
        // parked prefilled requests count as decode backlog, the pool
        // grows a replacement, and the requests recover instead of fail.
        let mut gen = WorkloadGen::new(53);
        let trace = gen.trace(WorkloadKind::Lphd, 32, 0.0, 0);
        let ev = FaultEvent {
            at: 100_000,
            kind: FaultKind::Crash,
            instance: Some(1),
            down: 0,
            factor: 1.0,
        };
        let cfg = ClusterConfig {
            n_prefill: 1,
            n_decode: 1,
            flip: None,
            elastic: Some(ElasticConfig {
                max_instances: 4,
                prefill_up_tokens: 100_000,
                decode_up_jobs: 1,
                ..Default::default()
            }),
            fault: Some(fault_cfg(vec![ev])),
            ..Default::default()
        };
        let m = run_cluster(cfg, trace);
        assert_eq!(m.finished + m.shed + m.failed, 32);
        assert!(m.scale_ups >= 1, "parked dispatches must pressure the pool to grow");
        assert_eq!(m.failed, 0, "the replacement instance must rescue every request");
    }

    #[test]
    fn prefix_cache_reuse_cuts_ttft_and_counts_hits() {
        use crate::prefixcache::PrefixCacheConfig;
        use crate::workload::PrefixPopulation;
        // A small hot prefix population over prompt-heavy traffic: with
        // the cache on, repeat prefixes skip their resident chunks, so
        // the run must record hits, saved tokens, and a strictly lower
        // mean TTFT than the cache-off twin of the same stamped trace.
        let mk_trace = || {
            let mut gen = WorkloadGen::new(59);
            gen.set_prefix(Some(PrefixPopulation { n_prefixes: 4, prefix_len: 512, zipf: 1.0 }));
            gen.trace(WorkloadKind::Hpld, 64, 0.0, 0)
        };
        let cold = run_cluster(small_cfg(), mk_trace());
        let warm = run_cluster(
            ClusterConfig {
                prefix_cache: Some(PrefixCacheConfig::default()),
                ..small_cfg()
            },
            mk_trace(),
        );
        assert_eq!(cold.cache_hits + cold.cache_misses, 0, "cache off records no lookups");
        assert!(warm.cache_hits > 0, "repeat prefixes must hit");
        assert!(warm.prefill_tokens_saved > 0, "hits must skip real prefill tokens");
        assert!(warm.cache_hit_rate() > 0.0 && warm.cache_hit_rate() <= 1.0);
        assert_eq!(warm.records.len(), 64, "reuse must not lose requests");
        assert!(
            warm.ttft_summary().mean < cold.ttft_summary().mean,
            "skipping prefill chunks must cut mean TTFT: warm {} vs cold {}",
            warm.ttft_summary().mean,
            cold.ttft_summary().mean
        );
    }

    #[test]
    fn stamped_trace_with_cache_off_is_bit_identical() {
        use crate::workload::PrefixPopulation;
        // Prefix stamps ride the requests, but with `prefix_cache: None`
        // the cluster must not consult them: the run is event-for-event
        // identical to the same generator without any stamps (the prefix
        // knob draws from its own RNG stream, so the traces agree).
        let plain = {
            let mut gen = WorkloadGen::new(61);
            run_cluster(small_cfg(), gen.trace(WorkloadKind::Mixed, 48, 30.0, 0))
        };
        let stamped = {
            let mut gen = WorkloadGen::new(61);
            gen.set_prefix(Some(PrefixPopulation::default()));
            run_cluster(small_cfg(), gen.trace(WorkloadKind::Mixed, 48, 30.0, 0))
        };
        assert_eq!(plain.makespan_us, stamped.makespan_us);
        assert_eq!(plain.events, stamped.events);
        assert_eq!(plain.records.len(), stamped.records.len());
        for (ra, rb) in plain.records.iter().zip(stamped.records.iter()) {
            assert_eq!((ra.first_token, ra.finished), (rb.first_token, rb.finished));
        }
        assert_eq!(stamped.cache_hits, 0);
        assert_eq!(stamped.prefill_tokens_saved, 0);
    }
}
