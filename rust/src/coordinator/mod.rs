//! The TetriInfer cluster (§3): centralized control plane (global scheduler
//! + cluster monitor), disaggregated prefill/decode instances, instance
//! flipping — driven as a deterministic discrete-event simulation over the
//! calibrated cost model. Real mode (rust/src/serve) reuses the same policy
//! modules with wall-clock engines.

pub mod cluster;
pub mod config;

pub use cluster::{run_cluster, Cluster};
pub use config::{ClusterConfig, ElasticConfig, FlipConfig, PredictorMode};
