//! Parallel sweep harness: fan scenario cells across `std::thread::scope`
//! workers — the crate is dependency-free (no rayon), so this is a
//! hand-rolled work queue over scoped threads.
//!
//! DistServe and TetriInfer both evaluate through exactly this kind of
//! large simulated sweep (hundreds of policy × workload × seed cells), so
//! sweep throughput directly bounds how many scenarios a PR can explore.
//! Each cell is one declarative [`Scenario`](crate::api::Scenario): the
//! arrival stream is regenerated inside the worker from the cell's
//! `trace_seed` (single-phase cells stream it — `Scenario::source` —
//! without ever materializing a trace, so even million-request scale
//! cells like scenarios/scale.json fit the grid at O(in-flight) memory
//! per worker), cells are cheap to describe, ship no request vectors
//! across threads, and are bit-identical to running sequentially —
//! results come back in input order regardless of which worker finished
//! first.
//!
//! Used by `examples/figures.rs` (figure regeneration) and
//! `benches/cluster.rs` (the BENCH_cluster.json perf baseline).

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::api::{Report, Scenario};

/// Worker count to use when the caller has no preference.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f` over `items` on up to `workers` scoped threads, pulling work
/// dynamically off a shared queue (cells vary wildly in cost — static
/// partitioning would leave workers idle behind one slow shard). Results
/// are returned in input order; a worker panic propagates.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let queue: Mutex<VecDeque<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect());
    let mut tagged: Vec<(usize, R)> = std::thread::scope(|s| {
        let queue = &queue;
        let f = &f;
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let job = queue.lock().expect("sweep queue poisoned").pop_front();
                        let Some((i, t)) = job else { break };
                        out.push((i, f(t)));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });
    tagged.sort_by_key(|&(i, _)| i);
    tagged.into_iter().map(|(_, r)| r).collect()
}

/// One sweep cell: a label plus a complete declarative experiment. The
/// driver (cluster vs baseline vs future systems) is the scenario's
/// `driver` registry key.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub label: String,
    pub scenario: Scenario,
}

impl SweepCell {
    pub fn new(label: impl Into<String>, scenario: Scenario) -> Self {
        SweepCell { label: label.into(), scenario }
    }
}

/// A finished cell: the full run [`Report`] (metrics + scenario echo +
/// host wall time of the DES run).
#[derive(Debug)]
pub struct CellResult {
    pub label: String,
    pub report: Report,
}

impl SweepCell {
    /// Run this cell to completion (deterministic given the scenario).
    /// Panics on an unknown driver key — sweep grids are authored in
    /// code, so a bad key is a bug, not an input error.
    pub fn run(self) -> CellResult {
        let report = self
            .scenario
            .run()
            .unwrap_or_else(|e| panic!("sweep cell '{}': {e}", self.label));
        CellResult { label: self.label, report }
    }
}

/// Fan every cell across `workers` threads; results in input order.
pub fn run_cells(cells: Vec<SweepCell>, workers: usize) -> Vec<CellResult> {
    parallel_map(cells, workers, SweepCell::run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadKind;

    #[test]
    fn parallel_map_preserves_input_order() {
        let got = parallel_map((0..100).collect(), 8, |x: u64| x * 3);
        let want: Vec<u64> = (0..100).map(|x| x * 3).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_handles_empty_and_single() {
        let empty: Vec<u64> = parallel_map(Vec::new(), 8, |x: u64| x);
        assert!(empty.is_empty());
        assert_eq!(parallel_map(vec![7u64], 8, |x| x + 1), vec![8]);
    }

    #[test]
    fn sweep_matches_sequential_runs() {
        let mk_cells = || -> Vec<SweepCell> {
            (0..6)
                .map(|seed| {
                    SweepCell::new(
                        format!("seed{seed}"),
                        Scenario::builder()
                            .workload(WorkloadKind::Mixed)
                            .requests(24)
                            .rate(16.0)
                            .seed(seed)
                            .topology(1, 2)
                            .build(),
                    )
                })
                .collect()
        };
        let serial: Vec<CellResult> = mk_cells().into_iter().map(SweepCell::run).collect();
        let parallel = run_cells(mk_cells(), 4);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(parallel.iter()) {
            assert_eq!(a.label, b.label);
            assert_eq!(
                a.report.metrics.makespan_us, b.report.metrics.makespan_us,
                "{}",
                a.label
            );
            assert_eq!(a.report.metrics.events, b.report.metrics.events, "{}", a.label);
            assert_eq!(a.report.metrics.records.len(), b.report.metrics.records.len());
        }
    }

    #[test]
    fn hybrid_and_elastic_cells_run_through_the_shared_engine() {
        let cells = vec![
            SweepCell::new(
                "hybrid",
                Scenario::builder()
                    .driver("hybrid")
                    .workload(WorkloadKind::Mixed)
                    .requests(24)
                    .rate(16.0)
                    .seed(5)
                    .coupled(1)
                    .build(),
            ),
            SweepCell::new(
                "elastic",
                Scenario::builder()
                    .workload(WorkloadKind::Hphd)
                    .requests(24)
                    .seed(5)
                    .flip_idle_ms(None)
                    .elastic(Some(crate::api::ElasticSpec {
                        max_instances: 5,
                        prefill_up_tokens: 512,
                        decode_up_jobs: 4,
                        ..Default::default()
                    }))
                    .build(),
            ),
        ];
        let res = run_cells(cells, 2);
        assert_eq!(res[0].report.driver, "hybrid");
        assert_eq!(res[0].report.metrics.records.len(), 24);
        assert_eq!(res[1].report.metrics.records.len(), 24);
        assert!(res[1].report.metrics.scale_ups >= 1, "elastic cell must scale");
    }

    #[test]
    fn baseline_cells_run_too() {
        let cells = vec![SweepCell::new(
            "base",
            Scenario::builder()
                .driver("vllm")
                .workload(WorkloadKind::Lpld)
                .requests(16)
                .seed(1)
                .build(),
        )];
        let res = run_cells(cells, 2);
        assert_eq!(res[0].report.metrics.records.len(), 16);
        assert_eq!(res[0].report.driver, "vllm");
    }

    #[test]
    #[should_panic(expected = "unknown driver")]
    fn unknown_driver_cell_panics_with_context() {
        SweepCell::new("bad", Scenario::builder().driver("nope").requests(1).build()).run();
    }
}
