//! Parallel sweep harness: fan (config × trace × seed) cells across
//! `std::thread::scope` workers — the crate is dependency-free (no rayon),
//! so this is a hand-rolled work queue over scoped threads.
//!
//! DistServe and TetriInfer both evaluate through exactly this kind of
//! large simulated sweep (hundreds of policy × workload × seed cells), so
//! sweep throughput directly bounds how many scenarios a PR can explore.
//! Each cell is an independent deterministic DES run: results are
//! bit-identical to running the cells sequentially, and they come back in
//! input order regardless of which worker finished first.
//!
//! Used by `examples/figures.rs` (figure regeneration) and
//! `benches/cluster.rs` (the BENCH_cluster.json perf baseline).

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::baseline::{run_baseline, BaselineConfig};
use crate::coordinator::{run_cluster, ClusterConfig};
use crate::metrics::RunMetrics;
use crate::workload::{WorkloadGen, WorkloadKind};

/// Worker count to use when the caller has no preference.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f` over `items` on up to `workers` scoped threads, pulling work
/// dynamically off a shared queue (cells vary wildly in cost — static
/// partitioning would leave workers idle behind one slow shard). Results
/// are returned in input order; a worker panic propagates.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let queue: Mutex<VecDeque<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect());
    let mut tagged: Vec<(usize, R)> = std::thread::scope(|s| {
        let queue = &queue;
        let f = &f;
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let job = queue.lock().expect("sweep queue poisoned").pop_front();
                        let Some((i, t)) = job else { break };
                        out.push((i, f(t)));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });
    tagged.sort_by_key(|&(i, _)| i);
    tagged.into_iter().map(|(_, r)| r).collect()
}

/// Which simulated system a cell drives.
#[derive(Clone, Debug)]
pub enum SweepSystem {
    Cluster(ClusterConfig),
    Baseline(BaselineConfig),
}

/// One sweep cell: a complete simulated experiment. The trace is
/// regenerated inside the worker from `(kind, n_requests, rate_per_sec,
/// trace_seed)`, so cells are cheap to describe and the sweep ships no
/// request vectors across threads.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub label: String,
    pub system: SweepSystem,
    pub kind: WorkloadKind,
    pub n_requests: usize,
    pub rate_per_sec: f64,
    pub trace_seed: u64,
}

/// A finished cell: its metrics plus the wall time the DES run took.
#[derive(Debug)]
pub struct CellResult {
    pub label: String,
    pub metrics: RunMetrics,
    pub wall_secs: f64,
}

impl SweepCell {
    /// Run this cell to completion (deterministic given the cell).
    pub fn run(self) -> CellResult {
        let trace = WorkloadGen::new(self.trace_seed)
            .trace(self.kind, self.n_requests, self.rate_per_sec, 0);
        let t = std::time::Instant::now();
        let metrics = match self.system {
            SweepSystem::Cluster(cfg) => run_cluster(cfg, trace),
            SweepSystem::Baseline(cfg) => run_baseline(cfg, trace),
        };
        CellResult { label: self.label, metrics, wall_secs: t.elapsed().as_secs_f64() }
    }
}

/// Fan every cell across `workers` threads; results in input order.
pub fn run_cells(cells: Vec<SweepCell>, workers: usize) -> Vec<CellResult> {
    parallel_map(cells, workers, SweepCell::run)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_input_order() {
        let got = parallel_map((0..100).collect(), 8, |x: u64| x * 3);
        let want: Vec<u64> = (0..100).map(|x| x * 3).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_handles_empty_and_single() {
        let empty: Vec<u64> = parallel_map(Vec::new(), 8, |x: u64| x);
        assert!(empty.is_empty());
        assert_eq!(parallel_map(vec![7u64], 8, |x| x + 1), vec![8]);
    }

    #[test]
    fn sweep_matches_sequential_runs() {
        let mk_cells = || -> Vec<SweepCell> {
            (0..6)
                .map(|seed| SweepCell {
                    label: format!("seed{seed}"),
                    system: SweepSystem::Cluster(ClusterConfig {
                        seed,
                        ..ClusterConfig::ts_roce(1, 2)
                    }),
                    kind: WorkloadKind::Mixed,
                    n_requests: 24,
                    rate_per_sec: 16.0,
                    trace_seed: seed,
                })
                .collect()
        };
        let serial: Vec<CellResult> = mk_cells().into_iter().map(SweepCell::run).collect();
        let parallel = run_cells(mk_cells(), 4);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(parallel.iter()) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.metrics.makespan_us, b.metrics.makespan_us, "{}", a.label);
            assert_eq!(a.metrics.events, b.metrics.events, "{}", a.label);
            assert_eq!(a.metrics.records.len(), b.metrics.records.len());
        }
    }

    #[test]
    fn baseline_cells_run_too() {
        let cells = vec![SweepCell {
            label: "base".into(),
            system: SweepSystem::Baseline(BaselineConfig::default()),
            kind: WorkloadKind::Lpld,
            n_requests: 16,
            rate_per_sec: 0.0,
            trace_seed: 1,
        }];
        let res = run_cells(cells, 2);
        assert_eq!(res[0].metrics.records.len(), 16);
    }
}
