//! Parallel sweep harness: fan scenario cells across `std::thread::scope`
//! workers — the crate is dependency-free (no rayon), so this is a
//! hand-rolled work queue over scoped threads.
//!
//! DistServe and TetriInfer both evaluate through exactly this kind of
//! large simulated sweep (hundreds of policy × workload × seed cells), so
//! sweep throughput directly bounds how many scenarios a PR can explore.
//! Each cell is one declarative [`Scenario`](crate::api::Scenario): the
//! arrival stream is regenerated inside the worker from the cell's
//! `trace_seed` (single-phase cells stream it — `Scenario::source` —
//! without ever materializing a trace, so even million-request scale
//! cells like scenarios/scale.json fit the grid at O(in-flight) memory
//! per worker), cells are cheap to describe, ship no request vectors
//! across threads, and are bit-identical to running sequentially —
//! results come back in input order regardless of which worker finished
//! first.
//!
//! Used by `examples/figures.rs` (figure regeneration) and
//! `benches/cluster.rs` (the BENCH_cluster.json perf baseline).

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::Mutex;

use crate::api::{Report, Scenario};
use crate::util::Json;

/// Worker count to use when the caller has no preference.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f` over `items` on up to `workers` scoped threads, pulling work
/// dynamically off a shared queue (cells vary wildly in cost — static
/// partitioning would leave workers idle behind one slow shard). Workers
/// are persistent for the whole sweep: each thread runs many cells, so
/// per-thread run state (the engine's salvaged core buffers — see
/// `sim::engine`) is reused across cells instead of reallocated per cell.
/// Work is pulled in chunks — one lock acquisition hands out several
/// cells — sized so every worker still gets multiple hand-outs and no one
/// starves behind a slow shard. Results are returned in input order
/// regardless of chunking; a worker panic propagates.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    // ≥ 4 hand-outs per worker keeps dynamic balancing effective while
    // amortizing queue contention across cheap cells.
    let chunk = (n / (workers * 4)).max(1);
    let queue: Mutex<VecDeque<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect());
    let mut tagged: Vec<(usize, R)> = std::thread::scope(|s| {
        let queue = &queue;
        let f = &f;
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(move || {
                    let mut out = Vec::new();
                    let mut batch: Vec<(usize, T)> = Vec::with_capacity(chunk);
                    loop {
                        {
                            let mut q = queue.lock().expect("sweep queue poisoned");
                            let take = chunk.min(q.len());
                            batch.extend(q.drain(..take));
                        }
                        if batch.is_empty() {
                            break;
                        }
                        for (i, t) in batch.drain(..) {
                            out.push((i, f(t)));
                        }
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });
    tagged.sort_by_key(|&(i, _)| i);
    tagged.into_iter().map(|(_, r)| r).collect()
}

/// One sweep cell: a label plus a complete declarative experiment. The
/// driver (cluster vs baseline vs future systems) is the scenario's
/// `driver` registry key.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub label: String,
    pub scenario: Scenario,
}

impl SweepCell {
    pub fn new(label: impl Into<String>, scenario: Scenario) -> Self {
        SweepCell { label: label.into(), scenario }
    }
}

/// A finished cell: the full run [`Report`] (metrics + scenario echo +
/// host wall time of the DES run).
#[derive(Debug)]
pub struct CellResult {
    pub label: String,
    pub report: Report,
}

impl SweepCell {
    /// Run this cell to completion (deterministic given the scenario).
    /// Per-request records are forced *off*: a grid holds O(cells)
    /// results, and every summary the CSV/JSON emitters read comes from
    /// the streaming histograms, so keeping per-request vectors alive
    /// across the whole sweep would cost O(cells × requests) memory for
    /// nothing. The virtual-time trajectory is identical either way (the
    /// knob only controls retention); use [`SweepCell::run_full`] when the
    /// caller genuinely needs the records. Panics on an unknown driver
    /// key — sweep grids are authored in code, so a bad key is a bug, not
    /// an input error.
    pub fn run(mut self) -> CellResult {
        self.scenario.records = false;
        self.run_full()
    }

    /// [`SweepCell::run`] without the record override — retention follows
    /// the scenario's own `records` knob (record-level parity tests and
    /// per-request figure post-processing go through here).
    pub fn run_full(self) -> CellResult {
        let report = self
            .scenario
            .run()
            .unwrap_or_else(|e| panic!("sweep cell '{}': {e}", self.label));
        CellResult { label: self.label, report }
    }
}

/// Fan every cell across `workers` threads; results in input order.
pub fn run_cells(cells: Vec<SweepCell>, workers: usize) -> Vec<CellResult> {
    parallel_map(cells, workers, SweepCell::run)
}

/// Header of [`results_csv`] — one place, so consumers and tests can't
/// drift from the emitter.
pub const RESULTS_CSV_HEADER: &str = "label,driver,finished,shed,ttft_mean_ms,ttft_p99_ms,\
jct_mean_ms,jct_p99_ms,resource_s,makespan_s,utilization,attained,slo_attainment,goodput_rps,\
cache_hit_rate,prefill_tokens_saved,overlap_ms";

/// Latency-attribution columns appended to [`RESULTS_CSV_HEADER`] when
/// at least one cell in the grid armed telemetry (telemetry-off grids
/// emit the exact legacy header — no drift for existing consumers).
pub const BREAKDOWN_CSV_COLUMNS: &str =
    ",queue_p99_ms,prefill_p99_ms,transfer_p99_ms,decode_p99_ms";

/// One CSV row per finished cell: the headline latency/resource columns
/// plus the SLO lens — shed count, attained count, attainment fraction,
/// and goodput (SLO-attained requests per second; equals plain request
/// throughput for classless cells). Summaries are computed once per row.
/// When any cell carries a telemetry summary, every row additionally
/// gets the [`BREAKDOWN_CSV_COLUMNS`] per-phase p99s (0.000 for cells
/// that ran telemetry-off or never visited a phase).
pub fn results_csv(results: &[CellResult]) -> String {
    let breakdown = results.iter().any(|r| r.report.telemetry.is_some());
    let mut out = String::from(RESULTS_CSV_HEADER);
    if breakdown {
        out.push_str(BREAKDOWN_CSV_COLUMNS);
    }
    out.push('\n');
    for r in results {
        let m = &r.report.metrics;
        let s = m.summaries();
        let finished = m.n_finished();
        let attainment =
            if finished == 0 { 1.0 } else { m.attained as f64 / finished as f64 };
        write!(
            out,
            "{},{},{},{},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3},{:.4},{},{:.4},{:.3},{:.4},{},{:.3}",
            r.label,
            r.report.driver,
            finished,
            m.shed,
            s.ttft.mean,
            s.ttft.p99,
            s.jct.mean,
            s.jct.p99,
            s.resource_s,
            m.makespan_us as f64 / 1e6,
            m.utilization(),
            m.attained,
            attainment,
            s.goodput_rps,
            m.cache_hit_rate(),
            m.prefill_tokens_saved,
            m.overlap_us as f64 / 1e3,
        )
        .expect("writing to a String cannot fail");
        if breakdown {
            for phase in ["queue", "prefill", "transfer", "decode"] {
                let p99 = r
                    .report
                    .telemetry
                    .as_ref()
                    .map(|t| t.phase_p99_ms(phase))
                    .unwrap_or(0.0);
                write!(out, ",{p99:.3}").expect("writing to a String cannot fail");
            }
        }
        out.push('\n');
    }
    out
}

/// Machine-readable twin of [`results_csv`]: an array of full
/// [`Report`]s (each already carries shed counts, per-class attainment,
/// and `goodput_rps` through the unified metrics serializer), labeled by
/// cell.
pub fn results_json(results: &[CellResult]) -> Json {
    Json::from(
        results
            .iter()
            .map(|r| {
                Json::obj([
                    ("label", Json::from(r.label.clone())),
                    ("report", r.report.to_json()),
                ])
            })
            .collect::<Vec<_>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadKind;

    #[test]
    fn parallel_map_preserves_input_order() {
        let got = parallel_map((0..100).collect(), 8, |x: u64| x * 3);
        let want: Vec<u64> = (0..100).map(|x| x * 3).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_handles_empty_and_single() {
        let empty: Vec<u64> = parallel_map(Vec::new(), 8, |x: u64| x);
        assert!(empty.is_empty());
        assert_eq!(parallel_map(vec![7u64], 8, |x| x + 1), vec![8]);
    }

    #[test]
    fn chunked_pulls_preserve_order_at_awkward_sizes() {
        // sizes around chunk boundaries: n < workers, n not divisible by
        // workers*4, n exactly workers*4, and a large prime
        for n in [3usize, 7, 8, 12, 97] {
            for workers in [2usize, 3, 5] {
                let got = parallel_map((0..n as u64).collect(), workers, |x: u64| x * x);
                let want: Vec<u64> = (0..n as u64).map(|x| x * x).collect();
                assert_eq!(got, want, "n={n} workers={workers}");
            }
        }
    }

    #[test]
    fn sweep_matches_sequential_runs() {
        let mk_cells = || -> Vec<SweepCell> {
            (0..6)
                .map(|seed| {
                    SweepCell::new(
                        format!("seed{seed}"),
                        Scenario::builder()
                            .workload(WorkloadKind::Mixed)
                            .requests(24)
                            .rate(16.0)
                            .seed(seed)
                            .topology(1, 2)
                            .build(),
                    )
                })
                .collect()
        };
        let serial: Vec<CellResult> = mk_cells().into_iter().map(SweepCell::run).collect();
        let parallel = run_cells(mk_cells(), 4);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(parallel.iter()) {
            assert_eq!(a.label, b.label);
            assert_eq!(
                a.report.metrics.makespan_us, b.report.metrics.makespan_us,
                "{}",
                a.label
            );
            assert_eq!(a.report.metrics.events, b.report.metrics.events, "{}", a.label);
            assert_eq!(a.report.metrics.n_finished(), b.report.metrics.n_finished());
            // the sweep path drops per-request records — O(cells) memory
            assert!(a.report.metrics.records.is_empty(), "{}", a.label);
        }
    }

    #[test]
    fn sharded_sweep_is_record_identical_to_serial() {
        // Persistent worker contexts: 2 workers over 8 cells means every
        // worker runs several cells on salvaged engine buffers — any state
        // leaking across cells through the reused buffers would perturb
        // some record here. Mixed drivers + faults widen the surface.
        let mk_cells = || -> Vec<SweepCell> {
            (0..8)
                .map(|i| {
                    let driver = if i % 2 == 0 { "tetri" } else { "vllm" };
                    let mut b = Scenario::builder()
                        .driver(driver)
                        .workload(WorkloadKind::Mixed)
                        .requests(32)
                        .rate(24.0)
                        .seed(i)
                        .topology(2, 2);
                    if i % 3 == 0 {
                        b = b.fault(crate::api::FaultSpec {
                            instance: Some(0),
                            down_ms: Some(40.0),
                            ..crate::api::FaultSpec::new(crate::api::FaultKind::Restart, 30.0)
                        });
                    }
                    SweepCell::new(format!("cell{i}"), b.build())
                })
                .collect()
        };
        let serial: Vec<CellResult> = mk_cells().into_iter().map(SweepCell::run_full).collect();
        let sharded = parallel_map(mk_cells(), 2, SweepCell::run_full);
        assert_eq!(serial.len(), sharded.len());
        for (a, b) in serial.iter().zip(sharded.iter()) {
            assert_eq!(a.label, b.label);
            let ra = &a.report.metrics.records;
            let rb = &b.report.metrics.records;
            assert_eq!(ra.len(), rb.len(), "{}", a.label);
            for (x, y) in ra.iter().zip(rb.iter()) {
                assert_eq!(
                    (x.id, x.arrival, x.first_token, x.finished),
                    (y.id, y.arrival, y.first_token, y.finished),
                    "{}: records must match field-for-field",
                    a.label
                );
            }
            assert_eq!(a.report.metrics.events, b.report.metrics.events, "{}", a.label);
        }
    }

    #[test]
    fn hybrid_and_elastic_cells_run_through_the_shared_engine() {
        let cells = vec![
            SweepCell::new(
                "hybrid",
                Scenario::builder()
                    .driver("hybrid")
                    .workload(WorkloadKind::Mixed)
                    .requests(24)
                    .rate(16.0)
                    .seed(5)
                    .coupled(1)
                    .build(),
            ),
            SweepCell::new(
                "elastic",
                Scenario::builder()
                    .workload(WorkloadKind::Hphd)
                    .requests(24)
                    .seed(5)
                    .flip_idle_ms(None)
                    .elastic(Some(crate::api::ElasticSpec {
                        max_instances: 5,
                        prefill_up_tokens: 512,
                        decode_up_jobs: 4,
                        ..Default::default()
                    }))
                    .build(),
            ),
        ];
        let res = run_cells(cells, 2);
        assert_eq!(res[0].report.driver, "hybrid");
        assert_eq!(res[0].report.metrics.n_finished(), 24);
        assert_eq!(res[1].report.metrics.n_finished(), 24);
        assert!(res[1].report.metrics.scale_ups >= 1, "elastic cell must scale");
    }

    #[test]
    fn csv_and_json_emitters_carry_the_goodput_column() {
        let cells = vec![
            SweepCell::new(
                "plain",
                Scenario::builder().workload(WorkloadKind::Lpld).requests(12).seed(2).build(),
            ),
            SweepCell::new(
                "classed",
                Scenario::builder()
                    .workload(WorkloadKind::Lpld)
                    .requests(12)
                    .seed(2)
                    .class(crate::api::ClassSpec {
                        name: "chat".into(),
                        ttft_ms: Some(0.001),
                        ..Default::default()
                    })
                    .build(),
            ),
        ];
        let results = run_cells(cells, 2);
        let csv = results_csv(&results);
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some(RESULTS_CSV_HEADER));
        assert!(RESULTS_CSV_HEADER.contains("goodput_rps") && RESULTS_CSV_HEADER.contains("shed"));
        let rows: Vec<&str> = lines.collect();
        assert_eq!(rows.len(), 2);
        assert!(rows[0].starts_with("plain,tetri,12,0,"), "{}", rows[0]);
        // the classless cell attains everything; the impossible 1 µs TTFT
        // deadline attains nothing → goodput 0
        let field = |row: &str, i: usize| row.split(',').nth(i).unwrap().to_string();
        assert_eq!(field(rows[0], 12), "1.0000", "classless attainment is vacuous");
        assert_eq!(field(rows[1], 11), "0", "impossible deadline: nothing attained");
        let j = results_json(&results);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].at(&["label"]).unwrap().as_str(), Some("plain"));
        assert!(arr[1].at(&["report", "metrics", "goodput_rps"]).is_some());
        assert!(arr[1].at(&["report", "metrics", "classes"]).is_some());
    }

    #[test]
    fn telemetry_armed_grids_grow_breakdown_columns() {
        let armed = Scenario::builder()
            .workload(WorkloadKind::Lpld)
            .requests(12)
            .seed(4)
            .telemetry(Some(crate::api::TelemetrySpec::default()))
            .build();
        let plain =
            Scenario::builder().workload(WorkloadKind::Lpld).requests(12).seed(4).build();
        let results =
            run_cells(vec![SweepCell::new("armed", armed), SweepCell::new("plain", plain)], 2);
        let csv = results_csv(&results);
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert_eq!(header, format!("{RESULTS_CSV_HEADER}{BREAKDOWN_CSV_COLUMNS}"));
        let cols = header.split(',').count();
        let rows: Vec<&str> = lines.collect();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.split(',').count() == cols), "rows match the header");
        // the armed cell attributes real decode time; the off cell pads 0s
        let field = |row: &str, i: usize| row.split(',').nth(i).unwrap().to_string();
        assert!(field(rows[0], cols - 1).parse::<f64>().unwrap() > 0.0, "{}", rows[0]);
        assert_eq!(field(rows[1], cols - 1), "0.000");
        // a fully telemetry-off grid emits the legacy header byte-for-byte
        let off = run_cells(
            vec![SweepCell::new(
                "p",
                Scenario::builder().workload(WorkloadKind::Lpld).requests(6).seed(1).build(),
            )],
            1,
        );
        assert!(results_csv(&off).starts_with(&format!("{RESULTS_CSV_HEADER}\n")));
    }

    #[test]
    fn baseline_cells_run_too() {
        let cells = vec![SweepCell::new(
            "base",
            Scenario::builder()
                .driver("vllm")
                .workload(WorkloadKind::Lpld)
                .requests(16)
                .seed(1)
                .build(),
        )];
        let res = run_cells(cells, 2);
        assert_eq!(res[0].report.metrics.n_finished(), 16);
        assert_eq!(res[0].report.driver, "vllm");
    }

    #[test]
    #[should_panic(expected = "unknown driver")]
    fn unknown_driver_cell_panics_with_context() {
        SweepCell::new("bad", Scenario::builder().driver("nope").requests(1).build()).run();
    }
}
