//! Property tests for the fault-injection subsystem (hand-rolled
//! generators: no proptest crate in the vendored environment; the failing
//! case's config is printed via assert context).
//!
//! The contract under test is the conservation law the recovery design
//! rests on: whatever chaos schedule runs against whichever driver,
//! every arrival ends in exactly one of three ledgers —
//!
//!     finished + shed + failed == arrivals
//!
//! — and the run *terminates* (a hung DES would time the suite out).
//! Alongside it, the per-request recovery invariants: a finished request
//! never spent more than the plan's retry budget, recovery latency is
//! only stamped on requests that were actually lost, and trajectories
//! stay causal (arrival ≤ first token ≤ finish) through any number of
//! crashes, restarts, link windows, and stragglers.

use tetri_infer::api::{FaultKind, FaultPlanSpec, FaultSpec, Scenario};
use tetri_infer::util::Pcg;
use tetri_infer::workload::WorkloadKind;

/// A random chaos schedule: 1–5 events of any kind over the first ~1.2 s
/// of virtual time, half with named targets (which may or may not be
/// alive when they fire — `Skipped` injections must be harmless), half
/// drawn from the plan's own RNG stream at fire time.
fn random_faults(rng: &mut Pcg) -> FaultPlanSpec {
    let n_events = 1 + rng.index(5);
    let mut events = Vec::new();
    for _ in 0..n_events {
        let kind = [
            FaultKind::Crash,
            FaultKind::Restart,
            FaultKind::LinkOut,
            FaultKind::LinkDegrade,
            FaultKind::Straggler,
        ][rng.index(5)];
        let at_ms = 10.0 + rng.f64() * 1200.0;
        let instance = if rng.f64() < 0.5 { Some(rng.index(4)) } else { None };
        let down_ms = Some(20.0 + rng.f64() * 600.0);
        let factor = match kind {
            FaultKind::LinkDegrade | FaultKind::Straggler => Some(1.5 + rng.f64() * 3.0),
            _ => None,
        };
        events.push(FaultSpec { kind, at_ms, instance, down_ms, factor });
    }
    FaultPlanSpec {
        events,
        retry_max: 2 + rng.index(4) as u32,
        backoff_ms: 5.0 + rng.f64() * 50.0,
        watermark: [0.0, 0.5, 0.9][rng.index(3)],
    }
}

fn random_scenario(rng: &mut Pcg, driver: &str) -> Scenario {
    Scenario {
        driver: driver.to_string(),
        workload: WorkloadKind::ALL[rng.index(5)],
        requests: 8 + rng.index(72),
        rate: [0.0, 16.0, 64.0][rng.index(3)],
        n_prefill: 1 + rng.index(2),
        n_decode: 1 + rng.index(2),
        n_coupled: if driver == "hybrid" { 1 } else { 0 },
        // elastic replacement for permanently dead slots, half the time
        elastic: if rng.f64() < 0.5 {
            Some(tetri_infer::ElasticSpec { max_instances: 6, ..Default::default() })
        } else {
            None
        },
        faults: Some(random_faults(rng)),
        ..Scenario::builder().seed(rng.next_u64() % (1 << 50)).build()
    }
}

#[test]
fn random_fault_plans_conserve_every_arrival_on_every_driver() {
    let mut rng = Pcg::new(0xfa17);
    for case in 0..36 {
        let driver = ["tetri", "vllm", "hybrid"][case % 3];
        let sc = random_scenario(&mut rng, driver);
        let total = sc.total_requests() as u64;
        let retry_max = sc.faults.as_ref().unwrap().retry_max;
        let ctx = || format!("case {case} ({driver}): {}", sc.summary_line());
        let m = sc.run().unwrap_or_else(|e| panic!("{}: {e}", ctx())).metrics;
        assert_eq!(
            m.finished + m.shed + m.failed,
            total,
            "{}: conservation violated (finished={} shed={} failed={})",
            ctx(),
            m.finished,
            m.shed,
            m.failed
        );
        assert_eq!(m.records.len() as u64, m.finished, "{}: one record per finish", ctx());
        for r in &m.records {
            assert!(
                r.retries <= retry_max,
                "{}: request {} finished after {} retries, budget {retry_max}",
                ctx(),
                r.id,
                r.retries
            );
            assert_eq!(
                r.recovered,
                r.retries > 0,
                "{}: recovered marks exactly the lost-then-finished requests ({:?})",
                ctx(),
                r
            );
            assert!(r.first_token >= r.arrival, "{}: TTFT causality {r:?}", ctx());
            assert!(r.finished >= r.first_token, "{}: JCT causality {r:?}", ctx());
        }
        assert_eq!(
            m.recovered,
            m.records.iter().filter(|r| r.recovered).count() as u64,
            "{}: recovery counter matches the records",
            ctx()
        );
        // failures can only come from spent retry budgets, which exist
        // only when something was actually injected
        if m.faults_injected == 0 {
            assert_eq!(m.failed, 0, "{}: failures require injections", ctx());
            assert_eq!(m.recovered, 0, "{}: recoveries require injections", ctx());
        }
    }
}

#[test]
fn fault_runs_are_deterministic() {
    let mut rng = Pcg::new(0xdead_fa17);
    for case in 0..9 {
        let driver = ["tetri", "vllm", "hybrid"][case % 3];
        let sc = random_scenario(&mut rng, driver);
        let a = sc.run().expect("run a").metrics;
        let b = sc.run().expect("run b").metrics;
        assert_eq!(a.makespan_us, b.makespan_us, "case {case} ({driver}): nondeterministic");
        assert_eq!(a.events, b.events, "case {case} ({driver})");
        assert_eq!(
            (a.finished, a.shed, a.failed, a.recovered, a.faults_injected),
            (b.finished, b.shed, b.failed, b.recovered, b.faults_injected),
            "case {case} ({driver}): outcome ledgers diverged"
        );
        for (ra, rb) in a.records.iter().zip(b.records.iter()) {
            assert_eq!(
                (ra.id, ra.arrival, ra.first_token, ra.finished, ra.retries),
                (rb.id, rb.arrival, rb.first_token, rb.finished, rb.retries),
                "case {case} ({driver}): record trajectories diverged"
            );
        }
    }
}

/// A crash-with-restart run on a single-decode cluster: the restarted
/// (fresh, empty) incarnation must never hand back pre-crash state.
/// Observable contract: with the only decode instance dead for the whole
/// downtime window, no multi-token request can finish inside it — every
/// decode-side completion after the crash lands strictly after the
/// restart, and the requests the crash caught mid-decode re-enter
/// prefill (recovered ≥ 1, each within the retry budget).
#[test]
fn restarted_instances_never_serve_pre_crash_state() {
    let crash_ms = 120.0;
    let down_ms = 300.0;
    let sc = Scenario {
        driver: "tetri".to_string(),
        workload: WorkloadKind::Lphd,
        requests: 32,
        rate: 0.0,
        n_prefill: 1,
        n_decode: 1,
        flip_idle_ms: None,
        faults: Some(FaultPlanSpec {
            events: vec![FaultSpec {
                instance: Some(1),
                down_ms: Some(down_ms),
                ..FaultSpec::new(FaultKind::Restart, crash_ms)
            }],
            ..Default::default()
        }),
        ..Scenario::builder().seed(7).build()
    };
    let m = sc.run().expect("run").metrics;
    assert_eq!(m.faults_injected, 1);
    assert_eq!(m.finished + m.shed + m.failed, 32);
    let crash_us = (crash_ms * 1e3) as u64;
    let restart_us = ((crash_ms + down_ms) * 1e3) as u64;
    assert!(
        m.records.iter().any(|r| r.finished > crash_us),
        "the crash must catch in-flight work"
    );
    for r in &m.records {
        // the dead window is decode-silent: only prefill-side completions
        // (single-token prompts) may finish inside it
        if r.decode_len > 1 {
            assert!(
                r.finished <= crash_us || r.finished > restart_us,
                "request {} finished at {} inside the downtime window ({}..{})",
                r.id,
                r.finished,
                crash_us,
                restart_us
            );
        }
    }
    assert!(m.recovered >= 1, "the crash must have lost resident decodes");
    assert!(m.failed == 0, "a restart within the backoff horizon loses nothing");
}
