//! Queue-parity property test: the calendar (timing-wheel) event queue
//! must pop in exactly the order the reference `BinaryHeap` queue pops —
//! global `(at, seq)` with FIFO among equal times — under randomized
//! interleavings of scheduling (near, far/overflow, clamped-past,
//! equal-time bursts), popping, and `advance_to` window jumps. This is
//! the determinism backstop for the million-request engine: the calendar
//! queue is a pure perf substitution, never a semantic one.

use tetri_infer::sim::{CalendarQueue, Event, HeapQueue};
use tetri_infer::util::Pcg;

/// One randomized episode: drive both queues with the identical op
/// sequence, asserting lock-step equality after every op, then drain.
fn episode(seed: u64, ops: usize) {
    let mut cal = CalendarQueue::new();
    let mut heap = HeapQueue::new();
    let mut rng = Pcg::new(seed);
    let mut next_id = 0u64;
    for op in 0..ops {
        match rng.weighted(&[0.5, 0.38, 0.12]) {
            0 => {
                // schedule a small burst across wildly different horizons
                let burst = 1 + rng.index(3);
                for _ in 0..burst {
                    let horizon = match rng.index(12) {
                        0 | 1 => 0,                             // tie with now
                        2..=5 => rng.range(1, 4_096),           // same bucket
                        6 | 7 => rng.range(1, 40_000),          // a few buckets out
                        8 => rng.range(1, 5_000_000),           // window edge
                        9 => rng.range(1, 300_000_000),         // deep overflow
                        10 => rng.range(1, 7_000_000_000),      // very deep overflow
                        _ => 0,
                    };
                    let mut at = cal.now() + horizon;
                    if rng.index(10) == 0 {
                        // exercise the past-time clamp
                        at = at.saturating_sub(rng.range(1, 100_000));
                    }
                    let ev = Event::Arrival(next_id);
                    next_id += 1;
                    cal.schedule_at(at, ev.clone());
                    heap.schedule_at(at, ev);
                }
            }
            1 => {
                let (a, b) = (cal.pop(), heap.pop());
                assert_eq!(a, b, "seed {seed} op {op}: divergent pop");
            }
            _ => {
                // jump the clock toward (never past) the next event — the
                // engine does this when delivering arrivals off-queue
                let bound = heap.peek_at();
                let step = rng.range(0, 10_000_000);
                let t = match bound {
                    Some(p) => cal.now() + step.min(p - cal.now()),
                    None => cal.now() + step,
                };
                cal.advance_to(t);
                heap.advance_to(t);
            }
        }
        assert_eq!(cal.now(), heap.now(), "seed {seed} op {op}: clocks diverged");
        assert_eq!(cal.len(), heap.len(), "seed {seed} op {op}: lengths diverged");
        assert_eq!(cal.is_empty(), heap.is_empty());
    }
    // drain to empty: the tail must agree event for event too
    loop {
        let (a, b) = (cal.pop(), heap.pop());
        assert_eq!(a, b, "seed {seed} drain: divergent pop");
        assert_eq!(cal.now(), heap.now());
        if a.is_none() {
            break;
        }
    }
}

#[test]
fn calendar_queue_matches_heap_queue_under_random_schedules() {
    for seed in 0..32 {
        episode(seed, 3_000);
    }
}

#[test]
fn calendar_queue_survives_long_quiet_gaps() {
    // sparse far-apart events: every pop crosses many empty buckets
    // and/or overflow jumps
    let mut cal = CalendarQueue::new();
    let mut heap = HeapQueue::new();
    let mut rng = Pcg::new(99);
    let mut at = 0u64;
    for i in 0..500u64 {
        at += rng.range(1, 120_000_000); // up to 2 virtual minutes apart
        cal.schedule_at(at, Event::Arrival(i));
        heap.schedule_at(at, Event::Arrival(i));
    }
    loop {
        let (a, b) = (cal.pop(), heap.pop());
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
    }
}

#[test]
fn equal_time_storms_stay_fifo() {
    // thousands of events at identical instants: the calendar bucket
    // heaps must preserve global seq order exactly
    let mut cal = CalendarQueue::new();
    let mut heap = HeapQueue::new();
    for round in 0..4u64 {
        let t = round * 1_000;
        for i in 0..2_000u64 {
            let id = round * 10_000 + i;
            cal.schedule_at(t, Event::Arrival(id));
            heap.schedule_at(t, Event::Arrival(id));
        }
    }
    let mut popped = 0;
    loop {
        let (a, b) = (cal.pop(), heap.pop());
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
        popped += 1;
    }
    assert_eq!(popped, 8_000);
}
