//! Queue-parity property test: the calendar (timing-wheel) event queue
//! must pop in exactly the order the reference `BinaryHeap` queue pops —
//! global `(at, seq)` with FIFO among equal times — under randomized
//! interleavings of scheduling (near, far/overflow, clamped-past,
//! equal-time bursts), popping, and `advance_to` window jumps. This is
//! the determinism backstop for the million-request engine: the calendar
//! queue is a pure perf substitution, never a semantic one.

use tetri_infer::sim::{CalendarQueue, Event, HeapQueue};
use tetri_infer::util::Pcg;

/// One randomized episode: drive both queues with the identical op
/// sequence, asserting lock-step equality after every op, then drain.
fn episode(seed: u64, ops: usize) {
    let mut cal = CalendarQueue::new();
    let mut heap = HeapQueue::new();
    let mut rng = Pcg::new(seed);
    let mut next_id = 0u64;
    for op in 0..ops {
        match rng.weighted(&[0.5, 0.38, 0.12]) {
            0 => {
                // schedule a small burst across wildly different horizons
                let burst = 1 + rng.index(3);
                for _ in 0..burst {
                    let horizon = match rng.index(12) {
                        0 | 1 => 0,                             // tie with now
                        2..=5 => rng.range(1, 4_096),           // same bucket
                        6 | 7 => rng.range(1, 40_000),          // a few buckets out
                        8 => rng.range(1, 5_000_000),           // window edge
                        9 => rng.range(1, 300_000_000),         // deep overflow
                        10 => rng.range(1, 7_000_000_000),      // very deep overflow
                        _ => 0,
                    };
                    let mut at = cal.now() + horizon;
                    if rng.index(10) == 0 {
                        // exercise the past-time clamp
                        at = at.saturating_sub(rng.range(1, 100_000));
                    }
                    let ev = Event::Arrival(next_id);
                    next_id += 1;
                    cal.schedule_at(at, ev.clone());
                    heap.schedule_at(at, ev);
                }
            }
            1 => {
                let (a, b) = (cal.pop(), heap.pop());
                assert_eq!(a, b, "seed {seed} op {op}: divergent pop");
            }
            _ => {
                // jump the clock toward (never past) the next event — the
                // engine does this when delivering arrivals off-queue
                let bound = heap.peek_at();
                let step = rng.range(0, 10_000_000);
                let t = match bound {
                    Some(p) => cal.now() + step.min(p - cal.now()),
                    None => cal.now() + step,
                };
                cal.advance_to(t);
                heap.advance_to(t);
            }
        }
        assert_eq!(cal.now(), heap.now(), "seed {seed} op {op}: clocks diverged");
        assert_eq!(cal.len(), heap.len(), "seed {seed} op {op}: lengths diverged");
        assert_eq!(cal.is_empty(), heap.is_empty());
    }
    // drain to empty: the tail must agree event for event too
    loop {
        let (a, b) = (cal.pop(), heap.pop());
        assert_eq!(a, b, "seed {seed} drain: divergent pop");
        assert_eq!(cal.now(), heap.now());
        if a.is_none() {
            break;
        }
    }
}

#[test]
fn calendar_queue_matches_heap_queue_under_random_schedules() {
    for seed in 0..32 {
        episode(seed, 3_000);
    }
}

#[test]
fn calendar_queue_survives_long_quiet_gaps() {
    // sparse far-apart events: every pop crosses many empty buckets
    // and/or overflow jumps
    let mut cal = CalendarQueue::new();
    let mut heap = HeapQueue::new();
    let mut rng = Pcg::new(99);
    let mut at = 0u64;
    for i in 0..500u64 {
        at += rng.range(1, 120_000_000); // up to 2 virtual minutes apart
        cal.schedule_at(at, Event::Arrival(i));
        heap.schedule_at(at, Event::Arrival(i));
    }
    loop {
        let (a, b) = (cal.pop(), heap.pop());
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
    }
}

#[test]
fn equal_time_storms_stay_fifo() {
    // thousands of events at identical instants: the calendar bucket
    // heaps must preserve global seq order exactly
    let mut cal = CalendarQueue::new();
    let mut heap = HeapQueue::new();
    for round in 0..4u64 {
        let t = round * 1_000;
        for i in 0..2_000u64 {
            let id = round * 10_000 + i;
            cal.schedule_at(t, Event::Arrival(id));
            heap.schedule_at(t, Event::Arrival(id));
        }
    }
    let mut popped = 0;
    loop {
        let (a, b) = (cal.pop(), heap.pop());
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
        popped += 1;
    }
    assert_eq!(popped, 8_000);
}

/// One randomized batched episode: the same burst of `(at, ev)` pairs is
/// admitted three ways — sequential `schedule_at` on a calendar queue,
/// `push_batch` on a second calendar queue, and `schedule_at` on the
/// reference heap — then all three must pop in lock-step. This pins
/// [`CalendarQueue::push_batch`]'s by-construction claim: identical
/// clamping, identical seq stamps, identical pop order.
fn batched_episode(seed: u64, ops: usize) {
    let mut cal_seq = CalendarQueue::new();
    let mut cal_batch = CalendarQueue::new();
    let mut heap = HeapQueue::new();
    let mut rng = Pcg::new(seed);
    let mut next_id = 0u64;
    for op in 0..ops {
        match rng.weighted(&[0.5, 0.38, 0.12]) {
            0 => {
                // bursts from 1 (degenerate batch) to ~40 events spanning
                // ties, same-bucket, cross-bucket, window-edge, and deep
                // overflow horizons — one batch can straddle all of them
                let burst = 1 + rng.index(40);
                let mut batch: Vec<(u64, Event)> = Vec::with_capacity(burst);
                for _ in 0..burst {
                    let horizon = match rng.index(12) {
                        0 | 1 => 0,                        // tie with now
                        2..=5 => rng.range(1, 4_096),      // same bucket
                        6 | 7 => rng.range(1, 40_000),     // a few buckets out
                        8 => rng.range(1, 5_000_000),      // window edge
                        9 => rng.range(1, 300_000_000),    // deep overflow
                        10 => rng.range(1, 7_000_000_000), // very deep overflow
                        _ => 0,
                    };
                    let mut at = cal_seq.now() + horizon;
                    if rng.index(10) == 0 {
                        // exercise the past-time clamp inside a batch
                        at = at.saturating_sub(rng.range(1, 100_000));
                    }
                    let ev = Event::Arrival(next_id);
                    next_id += 1;
                    batch.push((at, ev));
                }
                for (at, ev) in &batch {
                    cal_seq.schedule_at(*at, ev.clone());
                    heap.schedule_at(*at, ev.clone());
                }
                cal_batch.push_batch(batch);
            }
            1 => {
                let (a, b, c) = (cal_seq.pop(), cal_batch.pop(), heap.pop());
                assert_eq!(a, b, "seed {seed} op {op}: batch diverged from sequential");
                assert_eq!(a, c, "seed {seed} op {op}: calendar diverged from heap");
            }
            _ => {
                let bound = heap.peek_at();
                let step = rng.range(0, 10_000_000);
                let t = match bound {
                    Some(p) => cal_seq.now() + step.min(p - cal_seq.now()),
                    None => cal_seq.now() + step,
                };
                cal_seq.advance_to(t);
                cal_batch.advance_to(t);
                heap.advance_to(t);
            }
        }
        assert_eq!(cal_seq.now(), cal_batch.now(), "seed {seed} op {op}: clocks diverged");
        assert_eq!(cal_seq.len(), cal_batch.len(), "seed {seed} op {op}: lengths diverged");
    }
    loop {
        let (a, b, c) = (cal_seq.pop(), cal_batch.pop(), heap.pop());
        assert_eq!(a, b, "seed {seed} drain: batch diverged from sequential");
        assert_eq!(a, c, "seed {seed} drain: calendar diverged from heap");
        if a.is_none() {
            break;
        }
    }
}

#[test]
fn push_batch_matches_sequential_push_under_random_schedules() {
    for seed in 0..32 {
        batched_episode(seed, 2_000);
    }
}

#[test]
fn push_batch_equal_time_storms_stay_fifo() {
    // one giant batch of identical instants per round: per-bucket heapify
    // must preserve the global seq order sequential sift-ups produce
    let mut cal_seq = CalendarQueue::new();
    let mut cal_batch = CalendarQueue::new();
    for round in 0..4u64 {
        let t = round * 1_000;
        let batch: Vec<(u64, Event)> =
            (0..2_000u64).map(|i| (t, Event::Arrival(round * 10_000 + i))).collect();
        for (at, ev) in &batch {
            cal_seq.schedule_at(*at, ev.clone());
        }
        cal_batch.push_batch(batch);
    }
    loop {
        let (a, b) = (cal_seq.pop(), cal_batch.pop());
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
    }
}

#[test]
fn push_batch_overflow_migration_matches_sequential() {
    // one batch spanning the ring window and far beyond it, popped with
    // long quiet gaps so overflow events migrate into the ring mid-drain
    let mut cal_seq = CalendarQueue::new();
    let mut cal_batch = CalendarQueue::new();
    let mut rng = Pcg::new(7);
    let mut batch: Vec<(u64, Event)> = Vec::new();
    let mut at = 0u64;
    for i in 0..3_000u64 {
        at += rng.range(1, 60_000_000); // spans many full window slides
        batch.push((at, Event::Arrival(i)));
    }
    // shuffle so the batch is not pre-sorted by time
    for i in (1..batch.len()).rev() {
        batch.swap(i, rng.index(i + 1));
    }
    for (at, ev) in &batch {
        cal_seq.schedule_at(*at, ev.clone());
    }
    cal_batch.push_batch(batch);
    let mut popped = 0;
    loop {
        let (a, b) = (cal_seq.pop(), cal_batch.pop());
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
        popped += 1;
    }
    assert_eq!(popped, 3_000);
}

#[test]
fn push_batch_empty_and_reset_are_inert() {
    let mut cal = CalendarQueue::new();
    cal.push_batch(std::iter::empty());
    assert!(cal.is_empty());
    cal.push_batch([(5_000u64, Event::Arrival(0))]);
    assert_eq!(cal.len(), 1);
    // a reset queue behaves like a fresh one: same clamp, same seq order
    cal.reset();
    assert!(cal.is_empty());
    assert_eq!(cal.now(), 0);
    cal.push_batch([(10u64, Event::Arrival(1)), (10u64, Event::Arrival(2))]);
    assert_eq!(cal.pop(), Some((10, Event::Arrival(1))), "post-reset FIFO among ties");
    assert_eq!(cal.pop(), Some((10, Event::Arrival(2))));
}
