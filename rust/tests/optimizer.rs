//! Integration tests for the goodput-per-dollar optimizer (the PR-9
//! tentpole), covering the three soundness claims the search rests on:
//!
//!  1. **Trace-memoization parity** — replaying one shared, pre-sorted
//!     `Arc<Vec<Request>>` through `SharedTraceSource` is bit-identical
//!     to each cell generating and streaming its own arrivals, for every
//!     builtin driver (tetri / vllm / hybrid). If this breaks, the
//!     optimizer silently searches a *different* simulation than the one
//!     `sim run` would execute.
//!  2. **Determinism** — same spec + seed ⇒ byte-identical frontier JSON
//!     and CSV, at any worker count. The finals stage is wave-barriered
//!     precisely so the dominance incumbent never depends on thread
//!     scheduling.
//!  3. **Pruning soundness** — under a zero-tolerance config
//!     (keep_fraction 1.0 so halving discards nothing, min_attainment
//!     0.0 so no SLO aborts, prune_slack 0.0), successive halving plus
//!     dominance pruning must still recommend a cell whose full-run
//!     goodput/$ equals the exhaustive-sweep winner's. Hand-rolled
//!     property loop in the style of tests/proptest_slo.rs (Pcg-seeded,
//!     no external crates).

use std::sync::Arc;

use tetri_infer::api::{Driver as _, NullObserver, OptimizeGrid, Registry, Scenario};
use tetri_infer::metrics::RunMetrics;
use tetri_infer::optimizer::{self, value_of};
use tetri_infer::sim::SharedTraceSource;
use tetri_infer::sweep::run_cells;
use tetri_infer::util::{repo_root, Pcg};
use tetri_infer::workload::WorkloadKind;

/// `Request` / `RequestRecord` deliberately do not implement `PartialEq`,
/// so parity is asserted on the per-request field tuples that matter.
fn assert_metrics_identical(tag: &str, a: &RunMetrics, b: &RunMetrics) {
    assert_eq!(a.events, b.events, "{tag}: event counts diverged");
    assert_eq!(a.makespan_us, b.makespan_us, "{tag}: makespan diverged");
    assert_eq!(a.attained, b.attained, "{tag}: SLO attainment diverged");
    assert_eq!(a.records.len(), b.records.len(), "{tag}: record counts diverged");
    for (x, y) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(
            (x.id, x.arrival, x.first_token, x.finished),
            (y.id, y.arrival, y.first_token, y.finished),
            "{tag}: per-request timeline diverged"
        );
    }
}

/// Run `sc` the ordinary way (streamed arrival source, as `sim run` and
/// the exhaustive sweep do) and via a shared pre-sorted trace (as every
/// optimizer cell does), and demand bit-identical metrics.
fn assert_shared_trace_parity(tag: &str, sc: &Scenario) {
    let fresh = sc.run().unwrap_or_else(|e| panic!("{tag}: fresh run failed: {e}"));

    let mut trace = sc.trace();
    trace.sort_by_key(|r| r.arrival); // same stable sort as optimizer::TraceCache
    let shared = Arc::new(trace);
    let driver = Registry::builtin()
        .resolve(sc)
        .unwrap_or_else(|e| panic!("{tag}: driver resolve failed: {e}"));
    let mut src = SharedTraceSource::new(shared);
    let replay = driver.run_source(&mut src, &mut NullObserver);

    assert!(!fresh.metrics.aborted && !replay.metrics.aborted, "{tag}: no stop policy armed");
    assert_metrics_identical(tag, &fresh.metrics, &replay.metrics);
}

#[test]
fn shared_trace_replay_is_bit_identical_across_all_drivers() {
    for driver in ["tetri", "vllm", "hybrid"] {
        let mut sc = Scenario::builder()
            .name(&format!("parity-{driver}"))
            .driver(driver)
            .workload(WorkloadKind::Mixed)
            .requests(96)
            .rate(12.0)
            .seed(42)
            .topology(2, 2)
            .build();
        sc.records = true;
        assert_shared_trace_parity(driver, &sc);
    }

    // And the shipped classed search spec itself (SLO classes + admission),
    // since that is exactly what `sim optimize` replays through the cache.
    let path = repo_root().join("scenarios/optimize_mixed.json");
    let mut sc = Scenario::load(path.to_str().unwrap()).expect("optimize_mixed parses");
    sc.clamp_requests(48);
    sc.records = true;
    sc.optimize = None; // parity is about the run, not the search
    assert_shared_trace_parity("optimize_mixed", &sc);
}

#[test]
fn optimizer_output_is_byte_identical_across_runs_and_worker_counts() {
    let path = repo_root().join("scenarios/optimize_mixed.json");
    let mut sc = Scenario::load(path.to_str().unwrap()).expect("optimize_mixed parses");
    sc.clamp_requests(64);

    let runs: Vec<_> = [1, 1, 3]
        .iter()
        .map(|&w| optimizer::optimize(&sc, w).expect("search runs"))
        .collect();
    let json0 = runs[0].to_json().dump();
    let csv0 = runs[0].frontier_csv();
    assert!(!json0.is_empty() && !csv0.is_empty());
    for (i, r) in runs.iter().enumerate().skip(1) {
        assert_eq!(json0, r.to_json().dump(), "run {i} JSON drifted");
        assert_eq!(csv0, r.frontier_csv(), "run {i} CSV drifted");
    }
}

/// Zero-tolerance configs disable everything except dominance pruning, so
/// this is the direct test that the upper bound used to skip finalist
/// cells is a true bound: the exhaustive winner's goodput/$ must always
/// be the recommended value.
#[test]
fn halving_and_pruning_never_lose_the_exhaustive_winner() {
    let mut rng = Pcg::new(0x0917);
    for round in 0..6u64 {
        let driver = ["tetri", "vllm", "hybrid"][rng.index(3)];
        let workload =
            [WorkloadKind::Mixed, WorkloadKind::Lphd, WorkloadKind::Lpld][rng.index(3)];
        let requests = 48 + rng.index(49); // 48..=96
        let rate = 4.0 + 12.0 * rng.f64();
        let grid = OptimizeGrid {
            prefill: vec![1, 1 + rng.index(3)],
            decode: vec![1, 2 + rng.index(3)],
            chunk: if rng.index(2) == 0 { vec![256] } else { vec![256, 512] },
            start_fraction: 0.25,
            keep_fraction: 1.0,  // halving keeps every cell alive
            min_attainment: 0.0, // no SLO aborts
            prune: true,         // dominance pruning stays ON — the thing under test
            prune_slack: 0.0,
            ..OptimizeGrid::default()
        };
        let sc = Scenario::builder()
            .name(&format!("prop-{round}"))
            .driver(driver)
            .workload(workload)
            .requests(requests)
            .rate(rate)
            .seed(0xBEEF ^ round)
            .optimize(Some(grid))
            .build();

        // Ground truth: run every expanded cell at full length.
        let cells = optimizer::expand(&sc, sc.optimize.as_ref().unwrap());
        let exhaustive = run_cells(cells, 2);
        let best = exhaustive
            .iter()
            .map(|c| value_of(&c.report.metrics))
            .fold(f64::MIN, f64::max);
        assert!(best > 0.0, "round {round} ({driver}): exhaustive sweep produced no goodput");

        let res = optimizer::optimize(&sc, 2)
            .unwrap_or_else(|e| panic!("round {round} ({driver}): search failed: {e}"));
        assert_eq!(
            res.stats.halving_discarded, 0,
            "round {round}: keep_fraction 1.0 must discard nothing"
        );
        assert_eq!(res.stats.pruned_slo, 0, "round {round}: min_attainment 0 must abort nothing");
        let rec = res
            .recommended_cell()
            .unwrap_or_else(|| panic!("round {round} ({driver}): no recommendation"));
        let rec_value = value_of(&rec.report.metrics);
        // Exact f64 match is intended: the winner's full run is replayed
        // from the same shared trace, so its value is bit-identical to the
        // exhaustive run's (parity test above). A tiny relative epsilon
        // only papers over platform-specific float formatting, not logic.
        let tol = 1e-12 * best.abs().max(1.0);
        assert!(
            (rec_value - best).abs() <= tol,
            "round {round} ({driver}): dominance pruning lost the exhaustive winner: \
             recommended {rec_value} ({}), exhaustive best {best}",
            rec.label
        );

        // The frontier itself must be mutually non-dominated.
        let pts: Vec<(f64, f64)> = res
            .frontier
            .iter()
            .map(|c| {
                (c.report.metrics.goodput_rps(), optimizer::cost_per_hr(&c.report.metrics))
            })
            .collect();
        for (i, &(gi, ci)) in pts.iter().enumerate() {
            for (j, &(gj, cj)) in pts.iter().enumerate() {
                if i == j {
                    continue;
                }
                let dominates = gj >= gi && cj <= ci && (gj > gi || cj < ci);
                assert!(
                    !dominates,
                    "round {round}: frontier point {i} is dominated by {j}"
                );
            }
        }
    }
}
