//! SLO multi-tenancy invariants, property-tested with deterministic
//! pseudo-random configurations (hand-rolled loops — no proptest crate is
//! vendored):
//!
//!  * admission conservation: per class, `admitted (finished) + shed ==
//!    arrivals` at end of run, with nothing still queued — for every
//!    driver, under random class tables, rates, and limits;
//!  * token buckets never go negative and never bank more than burst +
//!    rate × elapsed;
//!  * the SLO prefill policy never inverts tier order within a committed
//!    scheduler pass;
//!  * classless scenarios are bit-identical to a single-default-class
//!    spec with admission off (the golden-parity guarantee, testable
//!    in-process);
//!  * the shipped overload spec sheds only the limited low tiers.

use std::collections::HashMap;

use tetri_infer::api::{ClassSpec, Observer, Scenario};
use tetri_infer::prefill::{PrefillPolicy, PrefillScheduler};
use tetri_infer::slo::TokenBucket;
use tetri_infer::types::{ReqMeta, Request, RequestRecord, TaskType, Us};
use tetri_infer::util::{repo_root, Pcg};
use tetri_infer::workload::WorkloadKind;

/// Counts arrivals/finishes/sheds per class (the conservation ledger).
#[derive(Default)]
struct Ledger {
    arrivals: HashMap<u8, u64>,
    finishes: HashMap<u8, u64>,
    sheds: HashMap<u8, u64>,
}

impl Observer for Ledger {
    fn on_arrival(&mut self, _now: Us, req: &Request) {
        *self.arrivals.entry(req.class).or_default() += 1;
    }

    fn on_finish(&mut self, _now: Us, rec: &RequestRecord) {
        *self.finishes.entry(rec.class).or_default() += 1;
    }

    fn on_shed(&mut self, _now: Us, req: &Request) {
        *self.sheds.entry(req.class).or_default() += 1;
    }
}

fn classed_scenario(seed: u64, driver: &str, rng: &mut Pcg) -> Scenario {
    let n_classes = 2 + rng.index(3); // 2..=4 classes
    let mut b = Scenario::builder()
        .name("slo-prop")
        .driver(driver)
        .workload(WorkloadKind::Mixed)
        .requests(48 + rng.index(48))
        .rate(8.0 + rng.f64() * 32.0)
        .seed(seed)
        .topology(1, 2)
        .flip_idle_ms(None)
        .prefill_policy(if rng.f64() < 0.5 { PrefillPolicy::Slo } else { PrefillPolicy::Sjf })
        .admission(true);
    for c in 0..n_classes {
        b = b.class(ClassSpec {
            name: format!("c{c}"),
            weight: 0.2 + rng.f64(),
            tier: c as u8,
            ttft_ms: if rng.f64() < 0.6 { Some(100.0 + rng.f64() * 2_000.0) } else { None },
            tpot_ms: if rng.f64() < 0.6 { Some(20.0 + rng.f64() * 300.0) } else { None },
            // tier 0 stays unlimited (the protected class); higher tiers
            // randomly draw rate and/or depth limits
            rate_limit: if c > 0 && rng.f64() < 0.7 { Some(0.5 + rng.f64() * 6.0) } else { None },
            burst: if c > 0 && rng.f64() < 0.5 { Some(1.0 + rng.f64() * 4.0) } else { None },
            max_queue: if c > 0 && rng.f64() < 0.5 { Some(4 + rng.index(40) as u64) } else { None },
        });
    }
    b.build()
}

#[test]
fn admission_conservation_per_class_across_drivers() {
    let mut rng = Pcg::new(0x510);
    for round in 0..8u64 {
        for driver in ["tetri", "vllm", "hybrid"] {
            let sc = classed_scenario(round + 1, driver, &mut rng);
            let total = sc.total_requests() as u64;
            let mut ledger = Ledger::default();
            let report = sc.run_with(&mut ledger).expect("driver resolves");
            let m = &report.metrics;
            let arrivals: u64 = ledger.arrivals.values().sum();
            let finishes: u64 = ledger.finishes.values().sum();
            let sheds: u64 = ledger.sheds.values().sum();
            assert_eq!(arrivals, total, "{driver}/{round}: every request must arrive once");
            assert_eq!(
                finishes + sheds,
                total,
                "{driver}/{round}: admitted + shed must conserve arrivals (none still queued)"
            );
            assert_eq!(m.shed, sheds, "{driver}/{round}: metrics shed total mismatch");
            assert_eq!(m.finished, finishes, "{driver}/{round}: metrics finish total mismatch");
            // per class: arrivals == finishes + sheds, and the metrics'
            // per-class ledger agrees with the observer's
            for (class, n) in &ledger.arrivals {
                let f = ledger.finishes.get(class).copied().unwrap_or(0);
                let s = ledger.sheds.get(class).copied().unwrap_or(0);
                assert_eq!(f + s, *n, "{driver}/{round}: class {class} leaked requests");
                let pc = &m.per_class[*class as usize];
                assert_eq!((pc.finished, pc.shed), (f, s), "{driver}/{round}: class {class}");
                assert!(
                    pc.attained <= pc.finished && pc.ttft_attained <= pc.finished,
                    "{driver}/{round}: attainment can never exceed finishes"
                );
            }
            // tier 0 declares no limits in this generator: never shed
            assert_eq!(
                ledger.sheds.get(&0).copied().unwrap_or(0),
                0,
                "{driver}/{round}: the unlimited tier-0 class must never shed"
            );
        }
    }
}

#[test]
fn token_bucket_level_bounded_and_admits_at_most_rate() {
    let mut rng = Pcg::new(7);
    for _ in 0..64 {
        let rate = rng.f64() * 20.0;
        let burst = 1.0 + rng.f64() * 10.0;
        let mut bucket = TokenBucket::new(rate, burst);
        let mut now: Us = 0;
        let mut admitted = 0u64;
        for _ in 0..400 {
            now += rng.range(0, 400_000);
            if bucket.try_take(now) {
                admitted += 1;
            }
            let level = bucket.level_tokens();
            assert!(level >= 0.0, "level can never go negative");
            assert!(level <= burst + 1.0, "level can never exceed burst (+1 floor)");
        }
        // upper bound: initial burst + refills over the elapsed window
        // (+1 slack for the integer-µtoken floor)
        let bound = burst.max(1.0) + rate * now as f64 / 1e6 + 1.0;
        assert!(
            (admitted as f64) <= bound,
            "admitted {admitted} exceeds burst+rate bound {bound} (rate {rate}, burst {burst})"
        );
    }
}

#[test]
fn slo_prefill_never_inverts_tiers_within_a_pass() {
    let mut rng = Pcg::new(11);
    for round in 0..64 {
        let n = 2 + rng.index(30);
        // one committed pass: sched_batch covers the whole queue
        let mut s = PrefillScheduler::new(PrefillPolicy::Slo, n.max(1));
        let n_classes = 1 + rng.index(4);
        let table: Vec<(u8, Us)> = (0..n_classes)
            .map(|c| {
                let dl = if rng.f64() < 0.5 { rng.range(1_000, 5_000_000) } else { Us::MAX };
                (c as u8, dl)
            })
            .collect();
        s.set_class_table(table.clone());
        for id in 0..n as u64 {
            s.push(ReqMeta {
                id,
                task: TaskType::Chat,
                class: rng.index(n_classes) as u8,
                arrival: rng.range(0, 1_000_000),
                prompt_len: rng.range(1, 1024) as u32,
                predicted: None,
                prefix: None,
            });
        }
        let mut last: Option<(u8, Us)> = None;
        while let Some(r) = s.pop() {
            let (tier, dl) = table[r.class as usize];
            let key = (tier, r.arrival.saturating_add(dl));
            if let Some(prev) = last {
                assert!(
                    prev.0 <= key.0,
                    "round {round}: tier inverted within a pass ({prev:?} before {key:?})"
                );
                if prev.0 == key.0 {
                    assert!(prev.1 <= key.1, "round {round}: EDF inverted within a tier");
                }
            }
            last = Some(key);
        }
    }
}

#[test]
fn classless_run_is_identical_to_single_default_class_admission_off() {
    // The bit-identity guarantee, testable in-process: a scenario with an
    // explicit single no-deadline class and admission off takes the same
    // trajectory — record for record — as the plain classless spec.
    let plain = Scenario::builder()
        .workload(WorkloadKind::Mixed)
        .requests(64)
        .rate(16.0)
        .seed(3)
        .topology(1, 2)
        .build();
    let classed = Scenario {
        classes: vec![ClassSpec::default()],
        admission: false,
        ..plain.clone()
    };
    for (a, b) in plain.trace().iter().zip(classed.trace().iter()) {
        assert_eq!(
            (a.id, a.arrival, a.prompt_len, a.decode_len, a.class),
            (b.id, b.arrival, b.prompt_len, b.decode_len, b.class),
            "single-class tables must not perturb the trace"
        );
    }
    for driver in ["tetri", "vllm"] {
        let a = Scenario { driver: driver.into(), ..plain.clone() }.run().unwrap().metrics;
        let b = Scenario { driver: driver.into(), ..classed.clone() }.run().unwrap().metrics;
        assert_eq!(a.makespan_us, b.makespan_us, "{driver}");
        assert_eq!(a.events, b.events, "{driver}");
        assert_eq!(a.records.len(), b.records.len(), "{driver}");
        for (ra, rb) in a.records.iter().zip(b.records.iter()) {
            assert_eq!(
                (ra.id, ra.arrival, ra.first_token, ra.finished),
                (rb.id, rb.arrival, rb.first_token, rb.finished),
                "{driver}: trajectory diverged"
            );
        }
        assert_eq!(b.shed, 0, "{driver}: admission off can never shed");
        assert_eq!(b.attained, b.finished, "{driver}: no deadlines ⇒ everything attains");
    }
}

#[test]
fn overload_spec_sheds_low_tiers_only_and_reports_attainment() {
    let path = repo_root().join("scenarios/slo_overload.json");
    let mut sc = Scenario::load(path.to_str().unwrap()).expect("shipped overload spec parses");
    sc.clamp_requests(192);
    let mut ledger = Ledger::default();
    let report = sc.run_with(&mut ledger).expect("tetri resolves");
    let m = &report.metrics;
    // the spike is absorbed by the rate/depth-limited low tiers...
    assert!(m.shed > 0, "the overload spec must actually shed");
    assert_eq!(
        ledger.sheds.get(&0).copied().unwrap_or(0),
        0,
        "tier-0 chat declares no limits and must never shed"
    );
    assert!(
        ledger.sheds.get(&2).copied().unwrap_or(0) > 0,
        "the rate-limited tier-2 batch class must absorb the spike"
    );
    // ...and the report carries the per-class SLO lens end-to-end
    assert_eq!(m.classes.len(), 3);
    assert!(m.per_class.len() >= 3);
    let rows = m.class_rows();
    assert_eq!(rows.len(), 3);
    assert!(rows[0].contains("chat") && rows[0].contains("attain"), "{}", rows[0]);
    let j = report.to_json();
    assert!(j.at(&["metrics", "classes"]).is_some(), "per-class JSON section");
    assert!(j.at(&["metrics", "goodput_rps"]).is_some());
    // goodput can never exceed overall finish throughput
    assert!(m.attained <= m.finished);
}
