//! Telemetry subsystem end-to-end (the observability tentpole):
//!
//!  1. observer-seam parity — arming telemetry (spans + sampler + trace)
//!     must leave the simulated trajectory bit-identical across all three
//!     drivers, record-for-record;
//!  2. span conservation — every finished request's phases partition its
//!     arrival→finish interval exactly, so the run-level `accounted_us`
//!     equals the JCT histogram's exact sum (slack 0 by design);
//!  3. Perfetto schema — the `--trace` export is valid Chrome
//!     trace-event JSON with the pinned event shapes and the pinned
//!     fault/recovery instant vocabulary.

use tetri_infer::api::{FaultKind, FaultSpec, Scenario, TelemetrySpec};
use tetri_infer::fault::{OBSERVED_FAULT_KINDS, OBSERVED_RECOVERY_KINDS};
use tetri_infer::telemetry::Phase;
use tetri_infer::util::{repo_root, Json};
use tetri_infer::workload::WorkloadKind;

/// A chaos-flavored scenario touching every span type: mixed workload,
/// disaggregated or coupled topology, and a mid-run instance restart so
/// retry/backoff and parked excursions actually happen.
fn chaotic(driver: &str, seed: u64) -> Scenario {
    Scenario::builder()
        .driver(driver)
        .workload(WorkloadKind::Mixed)
        .requests(64)
        .rate(32.0)
        .seed(seed)
        .topology(1, 2)
        .coupled(if driver == "hybrid" { 1 } else { 0 })
        .fault(FaultSpec {
            instance: Some(0),
            down_ms: Some(60.0),
            ..FaultSpec::new(FaultKind::Restart, 40.0)
        })
        .build()
}

#[test]
fn telemetry_on_is_bit_identical_to_off_across_all_drivers() {
    for driver in ["tetri", "vllm", "hybrid"] {
        let off = chaotic(driver, 9).run().expect("off run");
        let mut sc = chaotic(driver, 9);
        sc.telemetry = Some(TelemetrySpec { sample_ms: 5.0, max_samples: 64, trace: true });
        let on = sc.run().expect("armed run");
        assert_eq!(off.metrics.makespan_us, on.metrics.makespan_us, "{driver}");
        assert_eq!(off.metrics.events, on.metrics.events, "{driver}");
        assert_eq!(off.metrics.shed, on.metrics.shed, "{driver}");
        assert_eq!(off.metrics.failed, on.metrics.failed, "{driver}");
        assert_eq!(off.metrics.records.len(), on.metrics.records.len(), "{driver}");
        for (a, b) in off.metrics.records.iter().zip(on.metrics.records.iter()) {
            assert_eq!(
                (a.id, a.arrival, a.first_token, a.finished, a.retries),
                (b.id, b.arrival, b.first_token, b.finished, b.retries),
                "{driver}: records must match field-for-field"
            );
        }
        assert!(off.telemetry.is_none(), "{driver}: off runs carry no telemetry block");
        let t = on.telemetry.expect("armed run distills a summary");
        assert!(t.spans > 0, "{driver}");
        assert!(!t.series.is_empty(), "{driver}: the sampler must have fired");
        assert!(t.trace.is_some(), "{driver}: trace=true exports");
        // off-path JSON is byte-identical to a pre-telemetry report; the
        // armed report only *adds* the telemetry block
        let off_json = off.to_json().dump();
        assert!(!off_json.contains("\"telemetry\""), "{driver}");
        assert!(on.to_json().dump().contains("\"telemetry\""), "{driver}");
    }
}

#[test]
fn span_conservation_holds_across_drivers_and_seeds() {
    // hand-rolled property loop (the crate is dependency-free): whatever
    // the fault/retry/shed trajectory, finished requests' phase accruals
    // telescope to exactly arrival→finish, so the run-level sum matches
    // the exact JCT sum the metrics accumulated independently.
    for driver in ["tetri", "vllm", "hybrid"] {
        for seed in 0..4u64 {
            let mut sc = chaotic(driver, seed);
            sc.telemetry = Some(TelemetrySpec { sample_ms: 7.0, max_samples: 128, trace: false });
            let r = sc.run().expect("armed run");
            let t = r.telemetry.as_ref().expect("summary attached");
            assert_eq!(
                t.accounted_us,
                r.metrics.jct_sum_us(),
                "{driver} seed {seed}: Σ phases must equal Σ JCT (slack 0)"
            );
            let total: f64 = t.breakdown.iter().map(|p| p.sum_ms).sum();
            assert!(
                (total - t.accounted_ms()).abs() < 1e-6,
                "{driver} seed {seed}: breakdown rows must add up"
            );
            for p in &t.breakdown {
                assert!(
                    Phase::ALL.iter().any(|q| q.name() == p.phase),
                    "{driver} seed {seed}: unknown phase '{}'",
                    p.phase
                );
            }
        }
    }
}

#[test]
fn slo_overload_breakdown_reconciles_and_covers_classes() {
    let path = repo_root().join("scenarios/slo_overload.json");
    let mut sc = Scenario::load(path.to_str().unwrap()).expect("slo_overload parses");
    sc.requests = 128; // smoke horizon
    sc.telemetry = Some(TelemetrySpec { sample_ms: 10.0, max_samples: 512, trace: false });
    let r = sc.run().expect("runs");
    let m = &r.metrics;
    let t = r.telemetry.as_ref().expect("armed");
    assert_eq!(m.finished + m.shed + m.failed, 128, "conservation");
    assert!(m.shed > 0, "the overload scenario must shed");
    assert_eq!(t.accounted_us, m.jct_sum_us(), "shed requests never enter the breakdown");
    assert!(t.phase("queue").is_some() && t.phase("decode").is_some());
    // the spec declares three classes; every class that finished anything
    // gets its own per-phase breakdown, resolvable by name
    assert!(!t.classes.is_empty());
    for c in &t.classes {
        assert!(!c.phases.is_empty(), "class {} breakdown", c.class);
    }
    let lines = t.breakdown_lines();
    assert_eq!(lines.len(), t.breakdown.len());
    assert!(lines.iter().any(|l| l.contains("% of request time")), "{lines:?}");
}

#[test]
fn perfetto_export_schema_is_pinned() {
    let path = repo_root().join("scenarios/chaos_crash.json");
    let mut sc = Scenario::load(path.to_str().unwrap()).expect("chaos_crash parses");
    sc.telemetry = Some(TelemetrySpec { sample_ms: 25.0, max_samples: 256, trace: true });
    let r = sc.run().expect("runs");
    let t = r.telemetry.as_ref().expect("armed");
    let dumped = t.trace.as_ref().expect("trace armed").dump();
    let parsed = Json::parse(&dumped).expect("export must round-trip through the parser");
    assert_eq!(parsed.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    let evs = parsed.get("traceEvents").expect("top-level traceEvents").as_arr().unwrap();
    assert!(evs.len() > 10, "a chaos run leaves a real trace, got {}", evs.len());
    let (mut spans, mut instants, mut counters, mut metas) = (0u64, 0u64, 0u64, 0u64);
    for e in evs {
        let name = e.get("name").expect("every event is named").as_str().unwrap().to_string();
        assert!(e.get("pid").is_some(), "every event has a process lane");
        match e.get("ph").expect("every event has a phase").as_str().unwrap() {
            "X" => {
                spans += 1;
                assert!(e.get("ts").is_some() && e.get("dur").is_some(), "complete spans");
            }
            "i" => {
                instants += 1;
                assert_eq!(e.get("s").unwrap().as_str(), Some("g"), "global instants");
                assert!(
                    OBSERVED_FAULT_KINDS.contains(&name.as_str())
                        || OBSERVED_RECOVERY_KINDS.contains(&name.as_str()),
                    "instant '{name}' must come from the pinned fault/recovery vocabulary"
                );
            }
            "C" => {
                counters += 1;
                assert!(e.at(&["args", "value"]).is_some(), "counters carry a value");
            }
            "M" => {
                metas += 1;
                assert_eq!(name, "process_name");
                assert!(e.at(&["args", "name"]).is_some());
            }
            other => panic!("unexpected ph '{other}'"),
        }
    }
    assert!(spans > 0 && instants > 0 && counters > 0 && metas > 0);
    // request phase spans use the phase taxonomy; tid is the request lane
    let phase_names: Vec<&str> = Phase::ALL.iter().map(|p| p.name()).collect();
    assert!(
        evs.iter().any(|e| {
            e.get("ph").and_then(|p| p.as_str()) == Some("X")
                && e.get("name").and_then(|n| n.as_str()).is_some_and(|n| phase_names.contains(&n))
        }),
        "at least one request phase span exported"
    );
}
