//! Property tests over the scheduling/policy substrate: chunker coverage,
//! scheduler conservation, dispatcher membership, workload quadrants,
//! predictor range soundness. Hand-rolled generators (seeded PCG).

use std::collections::{HashMap, HashSet};

use tetri_infer::decode::{DecodePolicy, DecodeScheduler};
use tetri_infer::kvcache::PagedKvCache;
use tetri_infer::predictor::{OraclePredictor, Predictor};
use tetri_infer::prefill::{choose, Chunker, DecodeLoad, DispatchPolicy, PrefillPolicy, PrefillScheduler};
use tetri_infer::types::{Request, TaskType};
use tetri_infer::util::Pcg;
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

fn req(id: u64, plen: u32, dlen: u32) -> Request {
    Request {
        id,
        task: TaskType::Chat,
        class: 0,
        arrival: 0,
        prompt_len: plen,
        decode_len: dlen,
        predicted: None,
        prefix: None,
    }
}

#[test]
fn chunker_covers_every_token_exactly_once_random() {
    for seed in 0..30 {
        let mut rng = Pcg::new(seed);
        let chunk = [32u32, 128, 512, 513][rng.index(4)];
        let n = rng.range(1, 80) as usize;
        let mut c = Chunker::new(chunk);
        let mut want: HashMap<u64, u32> = Default::default();
        for i in 0..n {
            let plen = rng.range(1, 2000) as u32;
            want.insert(i as u64, plen);
            c.admit(req(i as u64, plen, 1).meta());
            // interleave admission and chunk production (arrival order)
            if rng.f64() < 0.5 {
                if let Some(ch) = c.next_chunk() {
                    assert!(ch.tokens <= chunk, "seed={seed}");
                    consume(&ch, &mut want, seed);
                }
            }
        }
        while let Some(ch) = c.next_chunk() {
            consume(&ch, &mut want, seed);
        }
        assert!(want.values().all(|&v| v == 0), "uncovered tokens: seed={seed} {want:?}");
    }
}

fn consume(ch: &tetri_infer::prefill::Chunk, want: &mut HashMap<u64, u32>, seed: u64) {
    let sum: u32 = ch.segments.iter().map(|s| s.len).sum();
    assert_eq!(sum, ch.tokens, "seed={seed}");
    for s in &ch.segments {
        let rem = want.get_mut(&s.req).unwrap();
        assert!(s.len <= *rem, "over-coverage seed={seed}");
        *rem -= s.len;
        if s.last {
            assert_eq!(*rem, 0, "`last` before prompt complete: seed={seed}");
        }
    }
}

#[test]
fn prefill_scheduler_conserves_requests() {
    for seed in 0..20 {
        let mut rng = Pcg::new(seed);
        let policy = [PrefillPolicy::Fcfs, PrefillPolicy::Sjf, PrefillPolicy::Ljf][rng.index(3)];
        let batch = rng.range(1, 40) as usize;
        let mut s = PrefillScheduler::new(policy, batch);
        let mut pushed = HashSet::new();
        let mut popped = HashSet::new();
        for i in 0..500u64 {
            if rng.f64() < 0.6 {
                s.push(req(i, rng.range(1, 1000) as u32, 1).meta());
                pushed.insert(i);
            } else if let Some(r) = s.pop() {
                assert!(popped.insert(r.id), "duplicate pop seed={seed}");
            }
        }
        while let Some(r) = s.pop() {
            assert!(popped.insert(r.id), "duplicate pop seed={seed}");
        }
        assert_eq!(pushed, popped, "lost/invented requests seed={seed}");
    }
}

#[test]
fn sjf_within_committed_batch_is_sorted() {
    for seed in 0..10 {
        let mut rng = Pcg::new(seed + 500);
        let mut s = PrefillScheduler::new(PrefillPolicy::Sjf, 16);
        for i in 0..16u64 {
            s.push(req(i, rng.range(1, 5000) as u32, 1).meta());
        }
        let lens: Vec<u32> = std::iter::from_fn(|| s.pop()).map(|r| r.prompt_len).collect();
        assert!(lens.windows(2).all(|w| w[0] <= w[1]), "not sorted: {lens:?}");
    }
}

#[test]
fn dispatcher_always_returns_a_member() {
    let mut rng = Pcg::new(9);
    for _ in 0..200 {
        let n = rng.range(1, 16) as usize;
        let loads: Vec<DecodeLoad> = (0..n)
            .map(|i| DecodeLoad {
                instance: i * 3, // non-contiguous ids
                free_kv_tokens: rng.range(0, 50_000),
                n_heavy: rng.range(0, 20) as u32,
                n_light: rng.range(0, 20) as u32,
                queue_len: rng.range(0, 10) as u32,
            })
            .collect();
        let ids: HashSet<usize> = loads.iter().map(|l| l.instance).collect();
        for pol in [DispatchPolicy::PowerOfTwo, DispatchPolicy::Random, DispatchPolicy::Imbalance, DispatchPolicy::LeastLoad] {
            let got = choose(&loads, rng.range(1, 1000) as u32, None, 200, pol, &mut rng).unwrap();
            assert!(ids.contains(&got), "{pol:?} returned non-member {got}");
        }
    }
}

#[test]
fn oracle_predictor_range_contains_truth_at_full_accuracy() {
    let mut p = OraclePredictor::ideal(3);
    let mut rng = Pcg::new(4);
    for _ in 0..2000 {
        let len = rng.range(1, 3000) as u32;
        let pred = p.predict(&[], len);
        assert!(pred.lo <= len, "lo {} > len {len}", pred.lo);
        assert!(len < pred.hi, "len {len} >= hi {}", pred.hi);
    }
}

#[test]
fn workload_generator_respects_bounds() {
    let mut g = WorkloadGen::new(17);
    for kind in WorkloadKind::ALL {
        for r in g.trace(kind, 300, 100.0, 0) {
            assert!(r.prompt_len >= 2 && r.prompt_len <= 1024, "{kind:?} {r:?}");
            assert!(r.decode_len >= 1 && r.decode_len <= 1599, "{kind:?} {r:?}");
        }
    }
}

#[test]
fn decode_scheduler_conserves_jobs_under_pressure() {
    for seed in 0..15 {
        let mut rng = Pcg::new(seed + 900);
        let policy = [DecodePolicy::Greedy, DecodePolicy::ReserveStatic, DecodePolicy::ReserveDynamic][rng.index(3)];
        let mut s = DecodeScheduler::new(policy, 200, 32);
        let mut kv = PagedKvCache::new(rng.range(16, 128) as u32, 8);
        let n = rng.range(5, 40);
        for i in 0..n {
            s.push(req(i, rng.range(1, 60) as u32, rng.range(1, 50) as u32));
        }
        let mut completed = 0u64;
        let mut done = Vec::new();
        for _ in 0..5_000 {
            s.admit(&mut kv);
            done.clear();
            s.step(&mut kv, &mut done);
            completed += done.len() as u64;
            kv.check_invariants().unwrap();
            if s.total_jobs() == 0 {
                break;
            }
        }
        assert_eq!(completed, n, "policy={policy:?} seed={seed}: jobs lost");
        assert_eq!(kv.n_live(), 0, "pages leaked seed={seed}");
    }
}

#[test]
fn decode_scheduler_heavy_light_totals_match_jobs() {
    let mut rng = Pcg::new(33);
    let mut s = DecodeScheduler::new(DecodePolicy::Greedy, 200, 64);
    let mut n = 0;
    for i in 0..50u64 {
        let mut r = req(i, 10, rng.range(1, 1000) as u32);
        if rng.f64() < 0.8 {
            let mut p = OraclePredictor::ideal(i);
            r.predicted = Some(p.predict(&[], r.decode_len));
        }
        s.push(r);
        n += 1;
    }
    let (h, l) = s.heavy_light();
    assert_eq!(h + l, n);
}
