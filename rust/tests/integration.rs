//! Cluster-level integration tests: random configurations and workloads
//! driven end-to-end through both systems, checking global serving
//! invariants (conservation, causality, determinism, accounting).

use std::collections::HashSet;

use tetri_infer::baseline::{run_baseline, BaselineConfig};
use tetri_infer::coordinator::{run_cluster, ClusterConfig, FlipConfig, PredictorMode};
use tetri_infer::decode::DecodePolicy;
use tetri_infer::fabric::Link;
use tetri_infer::metrics::RunMetrics;
use tetri_infer::prefill::{DispatchPolicy, PrefillPolicy};
use tetri_infer::util::Pcg;
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

fn check_run(m: &RunMetrics, n: usize, ctx: &str) {
    assert_eq!(m.records.len(), n, "{ctx}: not all requests completed");
    let mut ids = HashSet::new();
    for r in &m.records {
        assert!(ids.insert(r.id), "{ctx}: duplicate completion {r:?}");
        assert!(r.first_token >= r.arrival, "{ctx}: TTFT causality {r:?}");
        assert!(r.finished >= r.first_token, "{ctx}: JCT causality {r:?}");
        assert!(r.finished <= m.makespan_us, "{ctx}: finished after makespan {r:?}");
    }
    for (i, &b) in m.busy_us.iter().enumerate() {
        assert!(b <= m.makespan_us + 1, "{ctx}: instance {i} busier than the run is long");
    }
    assert!(m.resource_seconds() > 0.0, "{ctx}: no resource accounting");
}

fn random_cluster_cfg(rng: &mut Pcg) -> ClusterConfig {
    ClusterConfig {
        n_prefill: rng.range(1, 4) as usize,
        n_decode: rng.range(1, 5) as usize,
        chunk_size: [256u32, 512, 1024][rng.index(3)],
        prefill_policy: [PrefillPolicy::Fcfs, PrefillPolicy::Sjf, PrefillPolicy::Ljf][rng.index(3)],
        sched_batch: rng.range(1, 64) as usize,
        dispatch: [
            DispatchPolicy::PowerOfTwo,
            DispatchPolicy::Random,
            DispatchPolicy::Imbalance,
            DispatchPolicy::LeastLoad,
        ][rng.index(4)],
        decode_policy: [DecodePolicy::Greedy, DecodePolicy::ReserveStatic, DecodePolicy::ReserveDynamic][rng.index(3)],
        max_batch: [16u32, 64, 128][rng.index(3)],
        link: [Link::nvlink(), Link::roce200(), Link::indirect_socket()][rng.index(3)].clone(),
        predictor_mode: [PredictorMode::Parallel, PredictorMode::Sequential, PredictorMode::Disabled][rng.index(3)],
        predictor_accuracy: rng.f64(),
        flip: if rng.f64() < 0.5 {
            Some(FlipConfig { idle_us: rng.range(500_000, 5_000_000), ..Default::default() })
        } else {
            None
        },
        seed: rng.next_u64(),
        ..Default::default()
    }
}

#[test]
fn random_configs_complete_all_requests() {
    let mut rng = Pcg::new(2024);
    for case in 0..25 {
        let cfg = random_cluster_cfg(&mut rng);
        let kind = WorkloadKind::ALL[rng.index(5)];
        let n = rng.range(8, 96) as usize;
        let rate = [0.0, 4.0, 32.0][rng.index(3)];
        let trace = WorkloadGen::new(rng.next_u64()).trace(kind, n, rate, 0);
        let ctx = format!("case {case}: {kind:?} n={n} rate={rate} cfg={cfg:?}");
        let m = run_cluster(cfg, trace);
        check_run(&m, n, &ctx);
    }
}

#[test]
fn baseline_random_configs_complete_all_requests() {
    let mut rng = Pcg::new(4048);
    for case in 0..20 {
        let cfg = BaselineConfig {
            n_instances: rng.range(1, 4) as usize,
            prefill_batch: rng.range(1, 33) as usize,
            max_batch: [8u32, 16, 64][rng.index(3)],
            seed: rng.next_u64(),
            ..Default::default()
        };
        let kind = WorkloadKind::ALL[rng.index(5)];
        let n = rng.range(8, 96) as usize;
        let trace = WorkloadGen::new(rng.next_u64()).trace(kind, n, 8.0, 0);
        let m = run_baseline(cfg.clone(), trace);
        check_run(&m, n, &format!("baseline case {case}: {kind:?} n={n} {cfg:?}"));
    }
}

#[test]
fn identical_seeds_give_bitwise_identical_metrics() {
    let run = |seed: u64| {
        let trace = WorkloadGen::new(seed).trace(WorkloadKind::Mixed, 64, 16.0, 0);
        run_cluster(ClusterConfig { seed, ..ClusterConfig::ts_roce(2, 2) }, trace)
    };
    let (a, b) = (run(7), run(7));
    assert_eq!(a.makespan_us, b.makespan_us);
    assert_eq!(a.busy_us, b.busy_us);
    assert_eq!(a.flips, b.flips);
    let mut ra: Vec<_> = a.records.iter().map(|r| (r.id, r.first_token, r.finished)).collect();
    let mut rb: Vec<_> = b.records.iter().map(|r| (r.id, r.first_token, r.finished)).collect();
    ra.sort();
    rb.sort();
    assert_eq!(ra, rb);
}

#[test]
fn disaggregation_shields_ttft_from_heavy_decode() {
    // The paper's core claim, as an invariant: adding heavy-decode load
    // must not materially change TetriInfer's TTFT for light requests
    // (prefill instances never run decode), while the coupled baseline's
    // TTFT degrades.
    let light = WorkloadGen::new(1).trace(WorkloadKind::Lpld, 32, 16.0, 0);
    let mut heavy_gen = WorkloadGen::new(2);
    let mut mixed = light.clone();
    mixed.extend(heavy_gen.trace(WorkloadKind::Lphd, 32, 16.0, 0).into_iter().map(|mut r| {
        r.id += 10_000;
        r
    }));

    let ttft_light = |m: &RunMetrics| {
        let xs: Vec<f64> = m
            .records
            .iter()
            .filter(|r| r.id < 10_000)
            .map(|r| r.ttft() as f64)
            .collect();
        xs.iter().sum::<f64>() / xs.len() as f64
    };

    let t_alone = run_cluster(ClusterConfig { flip: None, ..ClusterConfig::ts_roce(1, 1) }, light.clone());
    let t_mixed = run_cluster(ClusterConfig { flip: None, ..ClusterConfig::ts_roce(1, 1) }, mixed.clone());
    let tetri_ratio = ttft_light(&t_mixed) / ttft_light(&t_alone);

    let b_mixed = run_baseline(BaselineConfig::default(), mixed);

    assert!(tetri_ratio < 2.0, "tetri TTFT should be nearly unaffected, got {tetri_ratio:.2}x");
    assert!(
        ttft_light(&t_mixed) < ttft_light(&b_mixed) / 2.0,
        "disaggregated TTFT must beat the coupled baseline under heavy-decode load: {} vs {}",
        ttft_light(&t_mixed),
        ttft_light(&b_mixed)
    );
}

#[test]
fn transfer_time_scales_with_link_bandwidth() {
    // JCT gap between socket and nvlink must be at least the KV wire-time
    // difference for heavy prompts.
    let trace = WorkloadGen::new(5).trace(WorkloadKind::Hphd, 32, 0.0, 0);
    let nv = run_cluster(
        ClusterConfig { flip: None, ..ClusterConfig::ts_nvlink(1, 1) },
        trace.clone(),
    );
    let sock = run_cluster(
        ClusterConfig { link: Link::indirect_socket(), flip: None, ..ClusterConfig::ts_roce(1, 1) },
        trace,
    );
    assert!(
        sock.jct_summary().mean > nv.jct_summary().mean,
        "indirect sockets must be slower end-to-end than NVLink: {} vs {}",
        sock.jct_summary().mean,
        nv.jct_summary().mean
    );
}

#[test]
fn predictor_modes_trade_latency_for_throughput() {
    // Figure 17's tradeoff: parallel mode taxes the main LLM (~10% per
    // co-run iteration) relative to running it alone; sequential mode
    // instead puts the prediction on each request's critical path.
    let mk = || WorkloadGen::new(11).trace(WorkloadKind::Lpld, 48, 4.0, 0);
    let off = run_cluster(
        ClusterConfig { predictor_mode: PredictorMode::Disabled, flip: None, ..ClusterConfig::ts_roce(1, 1) },
        mk(),
    );
    let par = run_cluster(
        ClusterConfig { predictor_mode: PredictorMode::Parallel, flip: None, ..ClusterConfig::ts_roce(1, 1) },
        mk(),
    );
    let seq = run_cluster(
        ClusterConfig { predictor_mode: PredictorMode::Sequential, flip: None, ..ClusterConfig::ts_roce(1, 1) },
        mk(),
    );
    assert!(
        par.ttft_summary().mean >= off.ttft_summary().mean,
        "parallel co-run cannot be faster than no predictor: {} vs {}",
        par.ttft_summary().mean,
        off.ttft_summary().mean
    );
    assert!(
        seq.ttft_summary().mean >= off.ttft_summary().mean,
        "sequential prediction cannot be faster than no predictor: {} vs {}",
        seq.ttft_summary().mean,
        off.ttft_summary().mean
    );
}

#[test]
fn swapped_tokens_accounted_under_memory_pressure() {
    use tetri_infer::costmodel::CostModel;
    let cost = CostModel { hbm_kv_bytes: 2e9, ..Default::default() }; // tiny HBM
    let m = run_cluster(
        ClusterConfig {
            cost,
            decode_policy: DecodePolicy::Greedy,
            flip: None,
            ..ClusterConfig::ts_roce(1, 1)
        },
        WorkloadGen::new(13).trace(WorkloadKind::Lphd, 64, 0.0, 0),
    );
    assert_eq!(m.records.len(), 64);
    assert!(m.swapped_tokens > 0, "tiny HBM + greedy must thrash");
}
