//! Golden determinism tests: pin `makespan_us` / mean JCT / mean TTFT for
//! fixed seeds across Mixed/Lphd/Lpld on both `run_cluster` and
//! `run_baseline`.
//!
//! Two layers of protection:
//!  1. every case runs twice and must be bitwise identical (determinism,
//!     checked unconditionally);
//!  2. the fingerprints are compared against `tests/golden_e2e.txt`. On
//!     the first run (no golden file yet — e.g. the environment that
//!     authored a refactor had no toolchain) the file is written
//!     ("blessed") and the test passes; from then on any drift in the
//!     simulated metrics fails with a diff. Commit the blessed file.
//!     To intentionally rebless after a semantics change: delete the file,
//!     re-run `cargo test`, commit the new version.
//!
//! Caveat, stated plainly: the seed repo could not build at all (no
//! Cargo.toml, and the authoring container shipped no Rust toolchain),
//! so no pre-refactor reference run exists. The first blessing therefore
//! pins *post*-refactor behavior as the baseline that all future PRs
//! must preserve — it cannot retroactively prove the arena/incremental
//! refactor changed nothing (that claim rests on the property tests and
//! the call-for-call parity of the refactor).

use std::fmt::Write as _;

use tetri_infer::api::{Driver as _, NullObserver, Registry, Scenario};
use tetri_infer::baseline::{run_baseline, BaselineConfig};
use tetri_infer::coordinator::{run_cluster, ClusterConfig};
use tetri_infer::metrics::RunMetrics;
use tetri_infer::util::repo_root;
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

const GOLDEN_PATH: &str = "tests/golden_e2e.txt";
const SEED: u64 = 42;

fn fingerprint(m: &RunMetrics) -> String {
    format!(
        "makespan_us={} jct_mean_ms={:.6} ttft_mean_ms={:.6} n={} swapped={} flips={} \
         scales=+{}/-{} shed={} attained={} failed={} recovered={} faults={} \
         hits={} misses={} saved={}",
        m.makespan_us,
        m.jct_summary().mean,
        m.ttft_summary().mean,
        m.records.len(),
        m.swapped_tokens,
        m.flips,
        m.scale_ups,
        m.scale_downs,
        m.shed,
        m.attained,
        m.failed,
        m.recovered,
        m.faults_injected,
        m.cache_hits,
        m.cache_misses,
        m.prefill_tokens_saved
    )
}

fn cases() -> Vec<(String, Box<dyn Fn() -> RunMetrics>)> {
    let mut out: Vec<(String, Box<dyn Fn() -> RunMetrics>)> = Vec::new();
    for kind in [WorkloadKind::Mixed, WorkloadKind::Lphd, WorkloadKind::Lpld] {
        out.push((
            format!("cluster/{}", kind.name()),
            Box::new(move || {
                let trace = WorkloadGen::new(SEED).trace(kind, 96, 16.0, 0);
                run_cluster(ClusterConfig { seed: SEED, ..ClusterConfig::ts_roce(1, 2) }, trace)
            }),
        ));
        out.push((
            format!("baseline/{}", kind.name()),
            Box::new(move || {
                let trace = WorkloadGen::new(SEED).trace(kind, 96, 16.0, 0);
                run_baseline(BaselineConfig { seed: SEED, ..Default::default() }, trace)
            }),
        ));
    }
    // one multi-prefill config (exercises the per-instance KV release)
    out.push((
        "cluster/Hpld-2p2d".to_string(),
        Box::new(|| {
            let trace = WorkloadGen::new(SEED).trace(WorkloadKind::Hpld, 64, 8.0, 0);
            run_cluster(
                ClusterConfig { seed: SEED, flip: None, ..ClusterConfig::ts_roce(2, 2) },
                trace,
            )
        }),
    ));
    // one spec-file-driven case: the scenario front door must stay pinned
    // to the same numbers as the raw-config path above
    out.push((
        "scenario/fig12-spec".to_string(),
        Box::new(|| {
            let path = repo_root().join("scenarios/fig12.json");
            let sc = Scenario::load(path.to_str().unwrap()).expect("fig12 spec parses");
            sc.run().expect("fig12 spec resolves").metrics
        }),
    ));
    // the instance-engine scenarios: the elastic pool (scale up under the
    // burst, drain + retire in the tail) and the hybrid fleet (coupled +
    // disaggregated instances sharing one engine) stay pinned too
    out.push((
        "scenario/elastic-spec".to_string(),
        Box::new(|| {
            let path = repo_root().join("scenarios/elastic.json");
            let sc = Scenario::load(path.to_str().unwrap()).expect("elastic spec parses");
            sc.run().expect("elastic spec resolves").metrics
        }),
    ));
    out.push((
        "scenario/hybrid-spec".to_string(),
        Box::new(|| {
            let path = repo_root().join("scenarios/hybrid.json");
            let sc = Scenario::load(path.to_str().unwrap()).expect("hybrid spec parses");
            sc.run().expect("hybrid spec resolves").metrics
        }),
    ));
    // the SLO multi-tenancy specs: workload classes, SLO-EDF prefill,
    // admission gate — steady state and overload (shed > 0) both pinned
    // end-to-end, so the new subsystem's trajectory can't drift silently
    out.push((
        "scenario/slo-mixed-spec".to_string(),
        Box::new(|| {
            let path = repo_root().join("scenarios/slo_mixed.json");
            let sc = Scenario::load(path.to_str().unwrap()).expect("slo_mixed spec parses");
            sc.run().expect("slo_mixed spec resolves").metrics
        }),
    ));
    out.push((
        "scenario/slo-overload-spec".to_string(),
        Box::new(|| {
            let path = repo_root().join("scenarios/slo_overload.json");
            let mut sc =
                Scenario::load(path.to_str().unwrap()).expect("slo_overload spec parses");
            sc.clamp_requests(128); // keep the golden run fast; sheds still occur
            sc.run().expect("slo_overload spec resolves").metrics
        }),
    ));
    // the chaos specs: crash → requeue → restart → elastic re-expansion,
    // link outage/degrade windows, and a correlated failure storm — the
    // fault subsystem's whole recovery trajectory stays pinned (the
    // fingerprint carries failed/recovered/faults counters)
    for name in ["chaos_crash", "chaos_link", "chaos_storm"] {
        out.push((
            format!("scenario/{name}-spec"),
            Box::new(move || {
                let path = repo_root().join(format!("scenarios/{name}.json"));
                let sc = Scenario::load(path.to_str().unwrap())
                    .unwrap_or_else(|e| panic!("{name} spec parses: {e}"));
                sc.run().unwrap_or_else(|e| panic!("{name} spec resolves: {e}")).metrics
            }),
        ));
    }
    // the prefix-cache specs: radix KV reuse on a skewed prefix population
    // (layer-wise transfer overlap in prefix_reuse, eviction churn in
    // multiturn) — the fingerprint carries hit/miss/saved counters, so the
    // cache's whole reuse trajectory stays pinned end-to-end
    for name in ["prefix_reuse", "multiturn"] {
        out.push((
            format!("scenario/{name}-spec"),
            Box::new(move || {
                let path = repo_root().join(format!("scenarios/{name}.json"));
                let sc = Scenario::load(path.to_str().unwrap())
                    .unwrap_or_else(|e| panic!("{name} spec parses: {e}"));
                sc.run().unwrap_or_else(|e| panic!("{name} spec resolves: {e}")).metrics
            }),
        ));
    }
    out
}

/// Fault-free parity: a scenario with `faults` absent and one carrying an
/// empty-events fault plan must produce bit-identical trajectories, on
/// both drivers — the fault subsystem's scheduling hooks may not perturb
/// a run that injects nothing.
#[test]
fn empty_fault_plan_runs_are_bit_identical_to_fault_free_runs() {
    use tetri_infer::api::FaultPlanSpec;
    for driver in ["tetri", "vllm", "hybrid"] {
        let base = Scenario {
            driver: driver.to_string(),
            workload: WorkloadKind::Mixed,
            requests: 64,
            rate: 24.0,
            n_prefill: 1,
            n_decode: 2,
            ..Scenario::builder().seed(SEED).build()
        };
        let faulted =
            Scenario { faults: Some(FaultPlanSpec::default()), ..base.clone() };
        let a = base.run().expect("fault-free run").metrics;
        let b = faulted.run().expect("empty-plan run").metrics;
        assert_records_identical(&format!("fault-parity/{driver}"), &a, &b);
        assert_eq!(a.events, b.events, "{driver}: event counts diverged");
        assert_eq!(b.faults_injected, 0);
        assert_eq!(b.failed, 0);
        assert!(b.records.iter().all(|r| r.retries == 0 && !r.recovered));
    }
}

#[test]
fn golden_metrics_are_deterministic_and_pinned() {
    let mut body = String::new();
    for (name, run) in cases() {
        let a = run();
        let b = run();
        // layer 1: bit-identical across runs in-process
        assert_eq!(a.makespan_us, b.makespan_us, "{name}: nondeterministic makespan");
        assert_eq!(a.events, b.events, "{name}: nondeterministic event count");
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{name}: nondeterministic metrics"
        );
        writeln!(body, "{name}: {}", fingerprint(&a)).unwrap();
    }
    // layer 2: compare against (or bless) the committed golden file
    match std::fs::read_to_string(GOLDEN_PATH) {
        Ok(golden) => {
            assert_eq!(
                golden, body,
                "simulated metrics drifted from {GOLDEN_PATH}.\n\
                 If the change is intentional (semantics changed), delete the\n\
                 file, re-run `cargo test`, and commit the re-blessed version.\n\
                 If not, the refactor changed behavior — fix it."
            );
        }
        Err(_) => {
            std::fs::write(GOLDEN_PATH, &body).expect("blessing golden file");
            eprintln!("golden: blessed {GOLDEN_PATH} (first run) — commit it");
        }
    }
}

/// Every shipped spec file must (a) survive a Scenario → JSON → Scenario
/// round trip as the identical value and (b) name a resolvable driver —
/// so scenarios/ can never rot silently.
#[test]
fn shipped_scenario_specs_round_trip_and_resolve() {
    let dir = repo_root().join("scenarios");
    let registry = Registry::builtin();
    let mut n = 0;
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("cannot list {}: {e}", dir.display()))
        .map(|e| e.expect("dir entry").path())
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    entries.sort();
    for path in entries {
        let path_str = path.to_str().unwrap();
        let sc = Scenario::load(path_str).unwrap_or_else(|e| panic!("{e}"));
        let reparsed = Scenario::from_str(&sc.to_json().dump())
            .unwrap_or_else(|e| panic!("{path_str}: {e}"));
        assert_eq!(reparsed, sc, "{path_str}: JSON round trip must be identity");
        registry.resolve(&sc).unwrap_or_else(|e| panic!("{path_str}: {e}"));
        n += 1;
    }
    assert!(n >= 24, "expected the shipped scenario set (incl. the telemetry demo), found {n} specs");
}

/// The optimizer tentpole pin: the shipped search spec — clamped to a
/// fast horizon — must produce the same frontier, recommendation, and
/// work accounting forever. Own golden file, same bless-on-first-run
/// protocol as `tests/golden_e2e.txt`; and the result must not depend on
/// the worker count (the search is wave-synchronized, results come back
/// in input order).
#[test]
fn optimizer_frontier_is_deterministic_and_pinned() {
    const OPT_GOLDEN_PATH: &str = "tests/golden_optimizer.txt";
    let path = repo_root().join("scenarios/optimize_mixed.json");
    let mut sc =
        Scenario::load(path.to_str().unwrap()).expect("optimize_mixed spec parses");
    sc.clamp_requests(96);
    let a = tetri_infer::optimizer::optimize(&sc, 2).expect("search runs");
    let b = tetri_infer::optimizer::optimize(&sc, 4).expect("search runs");
    assert_eq!(
        a.to_json().dump(),
        b.to_json().dump(),
        "frontier JSON must not depend on the worker count"
    );
    assert_eq!(a.frontier_csv(), b.frontier_csv());

    let mut body = String::new();
    writeln!(body, "spec=optimize_mixed requests=96").unwrap();
    for r in &a.frontier {
        writeln!(body, "frontier: {}", r.label).unwrap();
    }
    writeln!(
        body,
        "recommended: {}",
        a.recommended_cell().map(|r| r.label.as_str()).unwrap_or("none")
    )
    .unwrap();
    let st = &a.stats;
    writeln!(
        body,
        "stats: grid={} rungs={} halved={} slo_pruned={} dominance_pruned={} full_runs={} \
         events={}",
        st.grid_cells,
        st.rungs,
        st.halving_discarded,
        st.pruned_slo,
        st.pruned_dominance,
        st.full_runs,
        st.events_simulated
    )
    .unwrap();

    // golden-independent sanity: the grid expanded fully and the search
    // did strictly less event work than the exhaustive sweep estimate
    assert_eq!(st.grid_cells, 36, "3 prefill × 3 decode × 2 chunk × 2 policy");
    assert!(!a.frontier.is_empty(), "some topology must meet the SLO floor");
    assert!(
        st.fraction_of_exhaustive() < 1.0,
        "halving must beat the exhaustive sweep (got {})",
        st.fraction_of_exhaustive()
    );

    match std::fs::read_to_string(OPT_GOLDEN_PATH) {
        Ok(golden) => {
            assert_eq!(
                golden, body,
                "optimizer frontier drifted from {OPT_GOLDEN_PATH}.\n\
                 If the change is intentional (search semantics changed), delete\n\
                 the file, re-run `cargo test`, and commit the re-blessed version."
            );
        }
        Err(_) => {
            std::fs::write(OPT_GOLDEN_PATH, &body).expect("blessing optimizer golden");
            eprintln!("golden: blessed {OPT_GOLDEN_PATH} (first run) — commit it");
        }
    }
}

/// Assert two runs produced identical per-request trajectories: same
/// fingerprint and the same `RequestRecord`s, event for event.
fn assert_records_identical(name: &str, a: &RunMetrics, b: &RunMetrics) {
    assert_eq!(fingerprint(a), fingerprint(b), "{name}: fingerprints diverged");
    assert_eq!(a.records.len(), b.records.len(), "{name}: record counts diverged");
    for (ra, rb) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(
            (ra.id, ra.arrival, ra.first_token, ra.finished),
            (rb.id, rb.arrival, rb.first_token, rb.finished),
            "{name}: record trajectory diverged"
        );
    }
}

/// Every shipped spec, clamped to a fast size (decode chains still form).
fn clamped_specs() -> Vec<Scenario> {
    let dir = repo_root().join("scenarios");
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("cannot list {}: {e}", dir.display()))
        .map(|e| e.expect("dir entry").path())
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    entries.sort();
    entries
        .into_iter()
        .map(|p| {
            let mut sc = Scenario::load(p.to_str().unwrap()).unwrap_or_else(|e| panic!("{e}"));
            sc.clamp_requests(48);
            // parity runs compare records: retention must be on even for
            // scale specs that ship with it off
            sc.records = true;
            sc
        })
        .collect()
}

/// The macro-stepping tentpole invariant: collapsing decode/coupled
/// iteration chains into macro events is a pure perf refactor — for every
/// shipped scenario spec, per-iteration stepping (`macro_step: false`)
/// and macro stepping produce event-for-event identical `RequestRecord`s
/// and fingerprints, while macro stepping actually collapses events.
#[test]
fn macro_stepping_matches_per_iteration_stepping_on_shipped_specs() {
    let mut any_collapsed = false;
    for sc in clamped_specs() {
        let trace = sc.trace();
        let (on, off) = if sc.driver == "vllm" {
            let cfg = sc.baseline_config();
            let on = run_baseline(cfg.clone(), trace.clone());
            let off = run_baseline(BaselineConfig { macro_step: false, ..cfg }, trace);
            (on, off)
        } else {
            let mut cfg = sc.cluster_config();
            if sc.driver == "hybrid" && cfg.n_coupled == 0 {
                cfg.n_coupled = 1;
            }
            let on = run_cluster(cfg.clone(), trace.clone());
            let off = run_cluster(ClusterConfig { macro_step: false, ..cfg }, trace);
            (on, off)
        };
        assert_records_identical(&sc.name, &on, &off);
        assert_eq!(off.macro_steps, 0, "{}: reference stepping must not macro-step", sc.name);
        assert!(on.events <= off.events, "{}: macro stepping may never add events", sc.name);
        any_collapsed |= on.macro_steps > 0;
    }
    assert!(any_collapsed, "at least one spec must actually exercise macro-stepping");
}

/// The streaming-arrival tentpole invariant: pulling arrivals lazily from
/// the scenario's source (one pending request, recycled arena slots) is a
/// pure perf refactor — identical trajectory to preloading the whole
/// materialized trace, for every shipped spec.
#[test]
fn streamed_arrivals_match_preloaded_trace_on_shipped_specs() {
    let registry = Registry::builtin();
    for sc in clamped_specs() {
        let driver = registry.resolve(&sc).unwrap_or_else(|e| panic!("{e}"));
        let streamed = driver.run_source(sc.source().as_mut(), &mut NullObserver);
        let trace = sc.trace();
        let preloaded = driver.run(&trace, &mut NullObserver);
        assert_records_identical(&sc.name, &streamed.metrics, &preloaded.metrics);
        assert_eq!(
            streamed.metrics.events, preloaded.metrics.events,
            "{}: event counts diverged",
            sc.name
        );
        assert!(
            streamed.metrics.peak_arena <= trace.len(),
            "{}: arena may never exceed the trace",
            sc.name
        );
    }
}

/// A spec-file-loaded run and the equivalent builder-constructed run must
/// be the same experiment: identical `Scenario` values, and — run through
/// the driver registry — identical event counts and virtual timelines.
#[test]
fn spec_loaded_run_matches_builder_run_event_for_event() {
    let path = repo_root().join("scenarios/fig12.json");
    let from_spec = Scenario::load(path.to_str().unwrap()).expect("fig12 spec parses");
    let built = Scenario::builder()
        .name("fig12")
        .workload(WorkloadKind::Lphd)
        .requests(128)
        .rate(8.0)
        .seed(SEED)
        .build();
    assert_eq!(from_spec, built, "spec file and builder must agree on every knob");

    let a = from_spec.run().expect("spec run");
    let b = built.run().expect("builder run");
    assert_eq!(a.metrics.events, b.metrics.events, "event-for-event parity");
    assert_eq!(a.metrics.makespan_us, b.metrics.makespan_us);
    assert_eq!(fingerprint(&a.metrics), fingerprint(&b.metrics));
    assert_eq!(a.metrics.records.len(), b.metrics.records.len());
    for (ra, rb) in a.metrics.records.iter().zip(b.metrics.records.iter()) {
        assert_eq!(
            (ra.id, ra.arrival, ra.first_token, ra.finished),
            (rb.id, rb.arrival, rb.first_token, rb.finished)
        );
    }
}
