//! Golden determinism tests: pin `makespan_us` / mean JCT / mean TTFT for
//! fixed seeds across Mixed/Lphd/Lpld on both `run_cluster` and
//! `run_baseline`.
//!
//! Two layers of protection:
//!  1. every case runs twice and must be bitwise identical (determinism,
//!     checked unconditionally);
//!  2. the fingerprints are compared against `tests/golden_e2e.txt`. On
//!     the first run (no golden file yet — e.g. the environment that
//!     authored a refactor had no toolchain) the file is written
//!     ("blessed") and the test passes; from then on any drift in the
//!     simulated metrics fails with a diff. Commit the blessed file.
//!     To intentionally rebless after a semantics change: delete the file,
//!     re-run `cargo test`, commit the new version.
//!
//! Caveat, stated plainly: the seed repo could not build at all (no
//! Cargo.toml, and the authoring container shipped no Rust toolchain),
//! so no pre-refactor reference run exists. The first blessing therefore
//! pins *post*-refactor behavior as the baseline that all future PRs
//! must preserve — it cannot retroactively prove the arena/incremental
//! refactor changed nothing (that claim rests on the property tests and
//! the call-for-call parity of the refactor).

use std::fmt::Write as _;

use tetri_infer::baseline::{run_baseline, BaselineConfig};
use tetri_infer::coordinator::{run_cluster, ClusterConfig};
use tetri_infer::metrics::RunMetrics;
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

const GOLDEN_PATH: &str = "tests/golden_e2e.txt";
const SEED: u64 = 42;

fn fingerprint(m: &RunMetrics) -> String {
    format!(
        "makespan_us={} jct_mean_ms={:.6} ttft_mean_ms={:.6} n={} swapped={} flips={}",
        m.makespan_us,
        m.jct_summary().mean,
        m.ttft_summary().mean,
        m.records.len(),
        m.swapped_tokens,
        m.flips
    )
}

fn cases() -> Vec<(String, Box<dyn Fn() -> RunMetrics>)> {
    let mut out: Vec<(String, Box<dyn Fn() -> RunMetrics>)> = Vec::new();
    for kind in [WorkloadKind::Mixed, WorkloadKind::Lphd, WorkloadKind::Lpld] {
        out.push((
            format!("cluster/{}", kind.name()),
            Box::new(move || {
                let trace = WorkloadGen::new(SEED).trace(kind, 96, 16.0, 0);
                run_cluster(ClusterConfig { seed: SEED, ..ClusterConfig::ts_roce(1, 2) }, trace)
            }),
        ));
        out.push((
            format!("baseline/{}", kind.name()),
            Box::new(move || {
                let trace = WorkloadGen::new(SEED).trace(kind, 96, 16.0, 0);
                run_baseline(BaselineConfig { seed: SEED, ..Default::default() }, trace)
            }),
        ));
    }
    // one multi-prefill config (exercises the per-instance KV release)
    out.push((
        "cluster/Hpld-2p2d".to_string(),
        Box::new(|| {
            let trace = WorkloadGen::new(SEED).trace(WorkloadKind::Hpld, 64, 8.0, 0);
            run_cluster(
                ClusterConfig { seed: SEED, flip: None, ..ClusterConfig::ts_roce(2, 2) },
                trace,
            )
        }),
    ));
    out
}

#[test]
fn golden_metrics_are_deterministic_and_pinned() {
    let mut body = String::new();
    for (name, run) in cases() {
        let a = run();
        let b = run();
        // layer 1: bit-identical across runs in-process
        assert_eq!(a.makespan_us, b.makespan_us, "{name}: nondeterministic makespan");
        assert_eq!(a.events, b.events, "{name}: nondeterministic event count");
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{name}: nondeterministic metrics"
        );
        writeln!(body, "{name}: {}", fingerprint(&a)).unwrap();
    }
    // layer 2: compare against (or bless) the committed golden file
    match std::fs::read_to_string(GOLDEN_PATH) {
        Ok(golden) => {
            assert_eq!(
                golden, body,
                "simulated metrics drifted from {GOLDEN_PATH}.\n\
                 If the change is intentional (semantics changed), delete the\n\
                 file, re-run `cargo test`, and commit the re-blessed version.\n\
                 If not, the refactor changed behavior — fix it."
            );
        }
        Err(_) => {
            std::fs::write(GOLDEN_PATH, &body).expect("blessing golden file");
            eprintln!("golden: blessed {GOLDEN_PATH} (first run) — commit it");
        }
    }
}
