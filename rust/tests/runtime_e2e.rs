//! Real-mode integration over the PJRT runtime: loads artifacts/ (built by
//! `make artifacts`) and verifies the L3↔L2↔L1 numerical contracts from
//! the rust side. Skips gracefully when artifacts are absent (CI without
//! python), but `make test` always builds them first.
//!
//! The whole file needs the `pjrt` feature (runtime/serve are gated —
//! the default sim build is dependency-free).
#![cfg(feature = "pjrt")]

use tetri_infer::fabric::Link;
use tetri_infer::runtime::Engine;
use tetri_infer::serve::{ServeConfig, Server};
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping runtime_e2e: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::load("artifacts").expect("artifacts exist but failed to load"))
}

#[test]
fn manifest_and_weights_load() {
    let Some(e) = engine() else { return };
    let m = &e.manifest;
    assert!(m.model.chunk > 0 && m.model.max_seq % 128 == 0);
    assert_eq!(m.decode.page_size * m.decode.max_pages_per_req, m.model.max_seq);
    assert!(m.predictor_acc200.unwrap_or(0.0) > 0.5, "predictor should be fine-tuned");
}

#[test]
fn prefill_chunk_split_consistency() {
    // The L2 contract, checked through the real artifact: prefilling one
    // request as [chunk of n] must equal [chunk of k] + [chunk of n-k].
    let Some(e) = engine() else { return };
    let m = e.manifest.model.clone();
    let mut gen = WorkloadGen::new(42);
    let toks: Vec<i32> = (0..20).map(|_| gen.prompt_tokens(
        &tetri_infer::types::Request {
            id: 0,
            task: tetri_infer::types::TaskType::Chat,
            class: 0,
            arrival: 0,
            prompt_len: 20,
            decode_len: 8,
            predicted: None,
            prefix: None,
        },
        m.vocab as u32,
    ))
    .next()
    .unwrap();

    // one shot: valid = 20
    let mut k1 = vec![0f32; e.prefill_kv_numel()];
    let mut v1 = vec![0f32; e.prefill_kv_numel()];
    let mut padded = vec![0i32; m.chunk];
    padded[..20].copy_from_slice(&toks);
    let one = e.prefill_segment(&padded, 0, 20, &mut k1, &mut v1).unwrap();

    // split: 13 + 7
    let mut k2 = vec![0f32; e.prefill_kv_numel()];
    let mut v2 = vec![0f32; e.prefill_kv_numel()];
    let mut a = vec![0i32; m.chunk];
    a[..13].copy_from_slice(&toks[..13]);
    e.prefill_segment(&a, 0, 13, &mut k2, &mut v2).unwrap();
    let mut b = vec![0i32; m.chunk];
    b[..7].copy_from_slice(&toks[13..]);
    let two = e.prefill_segment(&b, 13, 7, &mut k2, &mut v2).unwrap();

    let max_err = one
        .iter()
        .zip(&two)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "chunk-split logits diverge: {max_err}");

    // the KV rows written must match too (first 20 rows of layer 0)
    let row = m.n_heads * m.d_head;
    let kv_err = k1[..20 * row]
        .iter()
        .zip(&k2[..20 * row])
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(kv_err < 1e-3, "chunk-split KV diverges: {kv_err}");
}

#[test]
fn predictor_returns_bucket_distribution() {
    let Some(e) = engine() else { return };
    let p = e.manifest.predictor.clone();
    let mut toks = vec![0i32; p.max_prompt];
    // marker + hint for a long decode (bucket >= 3): data.py layout
    toks[0] = 3; // creation
    toks[1] = 16 + 13; // hint ≈ 650 tokens
    for (i, t) in toks.iter_mut().enumerate().skip(2).take(10) {
        *t = 64 + i as i32;
    }
    let logits = e.predict_len(&toks, 12).unwrap();
    assert_eq!(logits.len(), p.n_buckets);
    let argmax = Engine::argmax(&logits);
    assert!(argmax >= 2, "650-token hint should land in a high bucket, got {argmax}");
}

#[test]
fn serve_pipeline_is_deterministic_and_complete() {
    let Some(e) = engine() else { return };
    let run = || {
        let mut gen = WorkloadGen::new(77);
        let trace = gen.trace(WorkloadKind::Mixed, 3, 0.0, 0);
        Server::new(&e, ServeConfig { emulate_link: None, ..Default::default() })
            .serve(trace, &mut gen)
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.metrics.records.len(), 3);
    assert_eq!(a.generated_tokens, b.generated_tokens);
    assert_eq!(a.sample_output, b.sample_output, "greedy decoding must be deterministic");
    assert!(a.transfer_bytes > 0, "KV must actually move prefill→decode");
}

#[test]
fn emulated_link_throttles_transfers() {
    let Some(e) = engine() else { return };
    let mut run = |link: Option<Link>| {
        let mut gen = WorkloadGen::new(5);
        // heavy prompts → enough KV bytes that the emulated wire time
        // dominates run-to-run compute noise
        let trace = gen.trace(WorkloadKind::Hpld, 2, 0.0, 0);
        Server::new(&e, ServeConfig { emulate_link: link, ..Default::default() })
            .serve(trace, &mut gen)
            .unwrap()
    };
    let raw = run(None);
    // 10 Mbps: ~2 MB of prompt KV per request ≈ seconds of wire time
    let slow = run(Some(Link { gbps: 0.01, ..Link::indirect_socket() }));
    let expected_wire =
        Link { gbps: 0.01, ..Link::indirect_socket() }.transfer_us(raw.transfer_bytes as f64);
    assert!(
        slow.wall_secs > raw.wall_secs + 0.5 * expected_wire as f64 / 1e6,
        "a 10 Mbps link must visibly slow serving: {} vs {} (+{}s wire)",
        slow.wall_secs,
        raw.wall_secs,
        expected_wire as f64 / 1e6
    );
}
