//! Property tests for the instance engine's role state machine and the
//! elastic pool (hand-rolled generators: no proptest crate in the
//! vendored environment; the failing seed is printed via assert context).
//!
//! Two layers:
//!
//!  1. pool-level — a random sequence of add / drain / flip / retire
//!     transitions keeps the `InstancePool` state machine consistent
//!     (epochs bump exactly on role exits, draining excludes instances
//!     from active counts without destroying their role state, retired
//!     slots are terminal, slot ids stay stable);
//!
//!  2. end-to-end — random cluster configurations that exercise every
//!     lifecycle edge at once (flips, elastic scale up/down, hybrid
//!     coupled instances) must never lose or double-finish a request,
//!     whatever the workload. This is the conservation contract the
//!     whole refactor rests on: requests are tracked by the shared
//!     engine arena, so no instance transition may strand one.

use std::collections::HashSet;

use tetri_infer::coordinator::{run_cluster, ClusterConfig, ElasticConfig, FlipConfig};
use tetri_infer::decode::DecodePolicy;
use tetri_infer::instance::{
    CoupledInst, DecodeInst, DrainTarget, InstancePool, InstanceState, PrefillInst,
};
use tetri_infer::prefill::PrefillPolicy;
use tetri_infer::types::Role;
use tetri_infer::util::Pcg;
use tetri_infer::workload::{WorkloadGen, WorkloadKind};

fn random_state(rng: &mut Pcg) -> InstanceState {
    match rng.index(3) {
        0 => InstanceState::Prefill(PrefillInst::new(PrefillPolicy::Sjf, 16, 512, false, 0)),
        1 => InstanceState::Decode(DecodeInst::new(DecodePolicy::Greedy, 200, 128, 64)),
        _ => InstanceState::Coupled(CoupledInst::new(64)),
    }
}

#[test]
fn random_pool_transitions_keep_the_state_machine_consistent() {
    for seed in 0..40u64 {
        let mut rng = Pcg::new(seed + 9_000);
        let mut pool = InstancePool::new();
        let mut epochs: Vec<u32> = Vec::new();
        let mut retired: Vec<bool> = Vec::new();
        let ctx = |seed: u64, op: usize| format!("seed {seed} op {op}");
        for op in 0..200 {
            let roll = rng.f64();
            if pool.is_empty() || roll < 0.2 {
                let st = random_state(&mut rng);
                let i = pool.push(st);
                assert_eq!(i, epochs.len(), "{}: ids are append-only", ctx(seed, op));
                epochs.push(0);
                retired.push(false);
            } else {
                let i = rng.index(pool.len());
                match rng.index(4) {
                    // begin a drain toward a random target
                    0 => {
                        if pool.accepts_work(i) {
                            let to = if rng.f64() < 0.5 {
                                DrainTarget::Retire
                            } else {
                                DrainTarget::Flip(Role::Decode)
                            };
                            pool.begin_drain(i, to);
                            assert!(
                                !pool.accepts_work(i),
                                "{}: draining instances must not accept work",
                                ctx(seed, op)
                            );
                            assert!(
                                pool.state(i).as_role().is_some(),
                                "{}: draining instances keep serving",
                                ctx(seed, op)
                            );
                        }
                    }
                    // flip an idle (thus drained) instance
                    1 => {
                        if pool.state(i).as_role().is_some() && pool.is_drained(i) {
                            let to = if rng.f64() < 0.5 { Role::Decode } else { Role::Prefill };
                            pool.begin_flip(i, to);
                            epochs[i] += 1;
                            assert!(
                                matches!(pool.state(i), InstanceState::Flipping { .. }),
                                "{}",
                                ctx(seed, op)
                            );
                        }
                    }
                    // land a flip
                    2 => {
                        let was_flipping =
                            matches!(pool.state(i), InstanceState::Flipping { .. });
                        let landed = pool.finish_flip(i, random_state(&mut rng));
                        assert_eq!(
                            landed, was_flipping,
                            "{}: finish_flip must land exactly on mid-flip slots",
                            ctx(seed, op)
                        );
                        if landed {
                            assert!(pool.accepts_work(i), "{}", ctx(seed, op));
                        }
                    }
                    // retire a drained instance
                    _ => {
                        if pool.state(i).as_role().is_some() && pool.is_drained(i) {
                            pool.retire(i);
                            epochs[i] += 1;
                            retired[i] = true;
                        }
                    }
                }
            }
            // global invariants after every op
            assert_eq!(pool.len(), epochs.len(), "{}: slots never disappear", ctx(seed, op));
            let mut live = 0;
            for (i, inst) in pool.iter().enumerate() {
                assert_eq!(
                    inst.epoch, epochs[i],
                    "{}: epoch must bump exactly on role exits",
                    ctx(seed, op)
                );
                if retired[i] {
                    assert!(
                        matches!(inst.state, InstanceState::Retired),
                        "{}: retirement is terminal",
                        ctx(seed, op)
                    );
                }
                if !matches!(inst.state, InstanceState::Retired) {
                    live += 1;
                }
            }
            assert_eq!(pool.n_live(), live, "{}", ctx(seed, op));
            let active_total = pool.n_active(Role::Prefill)
                + pool.n_active(Role::Decode)
                + pool.n_active(Role::Coupled);
            assert!(active_total <= live, "{}", ctx(seed, op));
        }
    }
}

fn random_lifecycle_cfg(rng: &mut Pcg) -> ClusterConfig {
    ClusterConfig {
        n_prefill: rng.range(1, 3) as usize,
        n_decode: rng.range(1, 3) as usize,
        n_coupled: rng.range(0, 3) as usize,
        flip: if rng.f64() < 0.5 {
            Some(FlipConfig { idle_us: rng.range(300_000, 2_000_000), ..Default::default() })
        } else {
            None
        },
        elastic: if rng.f64() < 0.7 {
            Some(ElasticConfig {
                max_instances: rng.range(3, 9) as usize,
                prefill_up_tokens: rng.range(512, 4096),
                decode_up_jobs: rng.range(2, 24),
                down_idle_us: rng.range(200_000, 2_000_000),
                min_per_role: 1,
            })
        } else {
            None
        },
        seed: rng.next_u64(),
        ..Default::default()
    }
}

#[test]
fn random_lifecycle_sequences_never_lose_or_double_finish_requests() {
    let mut rng = Pcg::new(31_337);
    for case in 0..20 {
        let cfg = random_lifecycle_cfg(&mut rng);
        let kind = WorkloadKind::ALL[rng.index(5)];
        let n = rng.range(8, 80) as usize;
        let rate = [0.0, 8.0, 48.0][rng.index(3)];
        let mut gen = WorkloadGen::new(rng.next_u64());
        let mut trace = gen.trace(kind, n, rate, 0);
        if rng.f64() < 0.5 {
            // a late quiet-tail straggler forces idle windows (drain +
            // retire and flip-back paths) while the run is still alive
            let mut tail = gen.trace(WorkloadKind::Lpld, 1, 0.0, 0);
            tail[0].arrival = 10_000_000 + rng.range(0, 10_000_000);
            trace.extend(tail);
        }
        let total = trace.len();
        let ctx = format!("case {case}: {kind:?} n={total} cfg={cfg:?}");
        let m = run_cluster(cfg, trace);
        assert_eq!(m.records.len(), total, "{ctx}: lost or stranded requests");
        let mut ids = HashSet::new();
        for r in &m.records {
            assert!(ids.insert(r.id), "{ctx}: double-finished request {}", r.id);
            assert!(r.first_token >= r.arrival, "{ctx}: TTFT causality {r:?}");
            assert!(r.finished >= r.first_token, "{ctx}: JCT causality {r:?}");
        }
        assert_eq!(
            m.busy_us.len(),
            m.alive_us.len(),
            "{ctx}: per-instance metric vectors must stay aligned"
        );
        assert!(
            m.busy_us.len() as u32 >= m.scale_ups,
            "{ctx}: scale-ups must grow the metric vectors"
        );
        assert!(m.scale_downs <= m.scale_ups + 4, "{ctx}: cannot retire more than ever existed");
    }
}
