//! Property test for the decode scheduler's incremental accounting: after
//! ANY random sequence of enqueue / inject / admit / step / step_n /
//! remove_running operations, the cached aggregates (running KV tokens,
//! reserved growth, heavy/light counts, swap-scarred count) must equal a
//! from-scratch recount. This is the behavior-preservation contract of
//! the O(1)-aggregate refactor (hand-rolled generators: no proptest crate
//! in the vendored environment; failing seed printed via assert context).

use tetri_infer::decode::{DecodeJob, DecodePolicy, DecodeScheduler};
use tetri_infer::kvcache::PagedKvCache;
use tetri_infer::predictor::{OraclePredictor, Predictor};
use tetri_infer::types::{Request, TaskType};
use tetri_infer::util::Pcg;

fn random_request(rng: &mut Pcg, id: u64, pred: &mut OraclePredictor) -> Request {
    let mut r = Request {
        id,
        task: TaskType::Chat,
        class: 0,
        arrival: 0,
        prompt_len: rng.range(1, 400) as u32,
        decode_len: rng.range(1, 300) as u32,
        predicted: None,
        prefix: None,
    };
    if rng.f64() < 0.7 {
        r.predicted = Some(pred.predict(&[], r.decode_len));
    }
    r
}

#[test]
fn aggregates_match_recount_after_random_op_sequences() {
    for seed in 0..25u64 {
        let mut rng = Pcg::new(seed + 7_000);
        let policy =
            [DecodePolicy::Greedy, DecodePolicy::ReserveStatic, DecodePolicy::ReserveDynamic]
                [rng.index(3)];
        let mut pred = OraclePredictor::new(200, 8, rng.f64(), seed);
        let max_batch = rng.range(2, 48) as u32;
        let mut s = DecodeScheduler::new(policy, 200, max_batch);
        // Small pools force constant preemption; big pools exercise the
        // smooth path.
        let mut kv = PagedKvCache::new(rng.range(8, 256) as u32, 8);
        let mut next_id = 0u64;
        let mut done = Vec::new();
        for op in 0..600 {
            let roll = rng.f64();
            if roll < 0.35 {
                // new arrival via the waiting line
                let r = random_request(&mut rng, next_id, &mut pred);
                next_id += 1;
                s.push(r);
            } else if roll < 0.45 {
                // a locally-prefilled job entering the batch directly
                // (baseline/real-mode path): it must own pages first.
                let r = random_request(&mut rng, next_id, &mut pred);
                if kv.can_fit(r.id, r.prompt_len + 1) {
                    next_id += 1;
                    kv.alloc(r.id, r.prompt_len + 1).unwrap();
                    let mut job = DecodeJob::new(r.meta(), r.decode_len);
                    job.generated = 1;
                    s.inject_running(job);
                }
            } else if roll < 0.55 {
                s.admit(&mut kv);
            } else if roll < 0.62 {
                // remove a random running job (single-token finisher path)
                if !s.running().is_empty() {
                    let id = s.running()[rng.index(s.running().len())].meta.id;
                    let job = s.remove_running(id).unwrap();
                    kv.release(job.meta.id);
                }
            } else if roll < 0.85 {
                done.clear();
                s.step(&mut kv, &mut done);
            } else {
                // the baseline's fixed-window variant
                let window = rng.range(0, 40) as usize;
                done.clear();
                s.step_n(&mut kv, window, &mut done);
            }
            kv.check_invariants().unwrap_or_else(|e| {
                panic!("kv invariant broken: seed={seed} op={op} policy={policy:?}: {e}")
            });
            assert_eq!(
                s.aggregates(),
                s.recount_aggregates(),
                "aggregate drift: seed={seed} op={op} policy={policy:?}"
            );
        }
        // Drain (bounded: a reserve policy can legitimately refuse a
        // head-of-line job whose mispredicted peak exceeds the whole pool,
        // so full drainage is not guaranteed — aggregate consistency is).
        for _ in 0..20_000 {
            s.admit(&mut kv);
            done.clear();
            s.step(&mut kv, &mut done);
            assert_eq!(s.aggregates(), s.recount_aggregates(), "drain drift seed={seed}");
            if s.total_jobs() == 0 {
                break;
            }
        }
        if s.total_jobs() == 0 {
            assert_eq!(
                s.aggregates(),
                tetri_infer::decode::SchedAggregates::default(),
                "aggregates must zero out when empty: seed={seed}"
            );
            assert_eq!(kv.n_live(), 0, "pages leaked: seed={seed}");
        }
    }
}

#[test]
fn preemption_victims_leave_from_the_back_in_order() {
    // Deterministic check of the O(1) victim rule: the newest running job
    // (batch tail) is evicted first, and the surviving batch keeps its
    // admission order — the exact semantics of the old O(n) scan.
    let mut s = DecodeScheduler::new(DecodePolicy::Greedy, 200, 64);
    // 9 usable pages of 8 tokens = 72 tokens of pool.
    let mut kv = PagedKvCache::new(10, 8);
    for id in 0..3u64 {
        s.push(Request {
            id,
            task: TaskType::Chat,
            class: 0,
            arrival: 0,
            prompt_len: 23, // 3 pages each → 9 pages total, pool full
            decode_len: 40,
            predicted: None,
            prefix: None,
        });
    }
    s.admit(&mut kv);
    assert_eq!(s.n_resident(), 3);
    let mut done = Vec::new();
    // Step 1 fills each job's spare slot; step 2 forces job 0 to grow a
    // page with the pool exhausted → the tail (job 2) is evicted.
    s.step(&mut kv, &mut done);
    assert_eq!(s.n_resident(), 3, "no eviction while spare slots remain");
    s.step(&mut kv, &mut done);
    let order: Vec<u64> = s.running().iter().map(|j| j.meta.id).collect();
    assert!(!order.contains(&2), "newest job must be the first victim: {order:?}");
    assert_eq!(order, vec![0, 1], "survivors keep admission order");
    assert_eq!(s.aggregates(), s.recount_aggregates());
}
