//! Property tests for the prefix-cache subsystem (hand-rolled: no
//! proptest crate in the vendored environment — random op sequences from
//! a seeded PCG, invariants checked after every operation, failing seed
//! printed).
//!
//! Properties:
//!   * without eviction pressure, lookup depth equals a naive
//!     longest-common-prefix oracle over every inserted chain;
//!   * eviction never frees a pinned block and capacity is never
//!     exceeded, whatever the op order;
//!   * with the cache off, a prefix-stamped trace runs event-for-event
//!     identical to its unstamped twin under all three drivers — the
//!     stamps ride a separate RNG stream and are pure metadata until a
//!     cache consumes them.

use tetri_infer::api::{BaselineDriver, ClusterDriver, Driver as _, NullObserver};
use tetri_infer::baseline::BaselineConfig;
use tetri_infer::coordinator::ClusterConfig;
use tetri_infer::prefixcache::{block_hashes, Pin, PrefixCache, PrefixCacheConfig};
use tetri_infer::util::Pcg;
use tetri_infer::workload::{PrefixPopulation, WorkloadGen, WorkloadKind};

/// Naive oracle: the longest whole-block prefix of `chain` shared with
/// any inserted chain (the trie answers exactly this when nothing has
/// been evicted).
fn naive_lcp(inserted: &[Vec<u64>], chain: &[u64]) -> u32 {
    let mut best = 0usize;
    for other in inserted {
        let m = other.iter().zip(chain.iter()).take_while(|(a, b)| a == b).count();
        best = best.max(m);
    }
    best as u32
}

#[test]
fn lookup_depth_matches_naive_lcp_oracle_without_eviction() {
    for seed in 0..25u64 {
        let mut rng = Pcg::new(seed);
        // capacity far above what 40 inserts of ≤ 8 blocks can use, so
        // the LRU never fires and the oracle stays exact
        let cfg = PrefixCacheConfig { capacity_pages: 1 << 16, ..Default::default() };
        let mut cache = PrefixCache::new(cfg);
        let blk = cfg.block_tokens;
        let mut inserted: Vec<Vec<u64>> = Vec::new();
        for step in 0..40 {
            let prefix_id = rng.range(0, 6);
            let len = rng.range(0, 8) as u32 * blk + rng.range(0, blk as u64) as u32;
            let chain = block_hashes(prefix_id, len, blk);
            let ctx = || format!("seed={seed} step={step} id={prefix_id} len={len}");
            assert_eq!(cache.peek(&chain), naive_lcp(&inserted, &chain), "{}", ctx());
            if rng.f64() < 0.7 {
                cache.insert(&chain);
                inserted.push(chain.clone());
                assert_eq!(cache.peek(&chain), chain.len() as u32, "own chain fully resident: {}", ctx());
            } else {
                let pin = cache.lookup_pin(&chain);
                assert_eq!(pin.depth(), naive_lcp(&inserted, &chain), "{}", ctx());
                cache.release(pin);
            }
            cache.check_invariants().unwrap_or_else(|e| panic!("{e} [{}]", ctx()));
        }
        // hashes are chained: sibling prefixes share nothing past their
        // first divergent block
        let a = block_hashes(100, 4 * blk, blk);
        let b = block_hashes(101, 4 * blk, blk);
        assert!(a.iter().zip(b.iter()).all(|(x, y)| x != y), "distinct ids must diverge at block 0");
    }
}

#[test]
fn eviction_never_frees_pinned_and_capacity_holds() {
    for seed in 50..80u64 {
        let mut rng = Pcg::new(seed);
        // tiny cache: a handful of blocks fit, so almost every insert evicts
        let cfg = PrefixCacheConfig {
            capacity_pages: 64,
            page_size: 16,
            block_tokens: 128, // 8 pages per block → 8 blocks max
        };
        let mut cache = PrefixCache::new(cfg);
        let blk = cfg.block_tokens;
        let mut pins: Vec<(Vec<u64>, Pin)> = Vec::new();
        for step in 0..300 {
            let ctx = || format!("seed={seed} step={step}");
            let roll = rng.f64();
            if roll < 0.4 {
                let chain = block_hashes(rng.range(0, 12), rng.range(1, 6) as u32 * blk, blk);
                cache.insert(&chain);
            } else if roll < 0.7 {
                let chain = block_hashes(rng.range(0, 12), rng.range(1, 6) as u32 * blk, blk);
                let pin = cache.lookup_pin(&chain);
                pins.push((chain, pin));
            } else if let Some((chain, pin)) = (!pins.is_empty())
                .then(|| pins.swap_remove(rng.index(pins.len())))
            {
                // pinned blocks must still be resident right up to release
                assert!(
                    cache.peek(&chain) >= pin.depth(),
                    "pinned prefix evicted: {} (peek {} < pin {})",
                    ctx(),
                    cache.peek(&chain),
                    pin.depth()
                );
                cache.release(pin);
            }
            assert!(
                cache.used_pages() <= cache.capacity_pages(),
                "capacity exceeded: {} ({} > {})",
                ctx(),
                cache.used_pages(),
                cache.capacity_pages()
            );
            cache.check_invariants().unwrap_or_else(|e| panic!("{e} [{}]", ctx()));
        }
        // once pressure happened at all, evictions must have been counted
        assert!(cache.stats.inserted_blocks > 0, "seed={seed}: no inserts landed");
    }
}

#[test]
fn crash_invalidation_empties_the_index_but_keeps_the_ledger() {
    let cfg = PrefixCacheConfig::default();
    let mut cache = PrefixCache::new(cfg);
    let chain = block_hashes(7, 4 * cfg.block_tokens, cfg.block_tokens);
    cache.insert(&chain);
    let pin = cache.lookup_pin(&chain);
    let hits_before = cache.stats.hits;
    assert!(hits_before > 0);
    cache.invalidate();
    assert_eq!(cache.peek(&chain), 0, "dead instance's blocks must be gone");
    assert_eq!(cache.used_pages(), 0);
    assert_eq!(cache.stats.hits, hits_before, "stats survive the epoch bump");
    assert!(cache.stats.invalidated_blocks >= 4);
    // a pin taken under the old epoch releases as a no-op
    cache.release(pin);
    cache.check_invariants().unwrap();
    // the next incarnation starts cold but counts into the same ledger
    let pin = cache.lookup_pin(&chain);
    assert_eq!(pin.depth(), 0);
    cache.release(pin);
    assert_eq!(cache.stats.misses, 1);
}

/// Stamped and unstamped twins of one trace: same seed, the stamped one
/// additionally draws prefix ranks from the dedicated prefix stream.
fn twin_traces(seed: u64, n: usize) -> (Vec<tetri_infer::types::Request>, Vec<tetri_infer::types::Request>) {
    let mut plain_gen = WorkloadGen::new(seed);
    let plain = plain_gen.trace(WorkloadKind::Mixed, n, 40.0, 0);
    let mut stamped_gen = WorkloadGen::new(seed);
    stamped_gen.set_prefix(Some(PrefixPopulation::default()));
    let stamped = stamped_gen.trace(WorkloadKind::Mixed, n, 40.0, 0);
    (plain, stamped)
}

#[test]
fn cache_off_stamped_traces_are_bit_identical_under_all_three_drivers() {
    let (plain, stamped) = twin_traces(97, 48);
    // the stamps themselves must not have perturbed the trace
    for (a, b) in plain.iter().zip(stamped.iter()) {
        assert_eq!((a.id, a.arrival, a.prompt_len, a.decode_len, a.task), (b.id, b.arrival, b.prompt_len, b.decode_len, b.task));
        assert!(a.prefix.is_none() && b.prefix.is_some());
    }
    let runs: [(&str, Box<dyn Fn(&[tetri_infer::types::Request]) -> tetri_infer::metrics::RunMetrics>); 3] = [
        (
            "tetri",
            Box::new(|t| {
                ClusterDriver::from_config(ClusterConfig::default()).run(t, &mut NullObserver).metrics
            }),
        ),
        (
            "vllm",
            Box::new(|t| {
                BaselineDriver::from_config(BaselineConfig::default()).run(t, &mut NullObserver).metrics
            }),
        ),
        (
            "hybrid",
            Box::new(|t| {
                let cfg = ClusterConfig { n_coupled: 1, ..Default::default() };
                ClusterDriver::from_config(cfg).run(t, &mut NullObserver).metrics
            }),
        ),
    ];
    for (name, run) in &runs {
        let a = run(&plain);
        let b = run(&stamped);
        assert_eq!(a.makespan_us, b.makespan_us, "{name}: makespan diverged");
        assert_eq!(a.events, b.events, "{name}: event count diverged");
        assert_eq!(a.records.len(), b.records.len(), "{name}");
        for (ra, rb) in a.records.iter().zip(b.records.iter()) {
            assert_eq!(
                (ra.id, ra.first_token, ra.finished),
                (rb.id, rb.first_token, rb.finished),
                "{name}: per-request trajectory diverged"
            );
        }
        assert_eq!(b.cache_hits + b.cache_misses, 0, "{name}: cache off must never look up");
    }
}
