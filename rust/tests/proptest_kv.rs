//! Property tests for the paged KV cache (hand-rolled: no proptest crate
//! in the vendored environment — random op sequences from a seeded PCG,
//! invariants checked after every operation, failing seed printed).
//!
//! Invariants (the decode artifact relies on all of them):
//!   * page 0 (the trash page) is never allocated;
//!   * no page is owned twice — not by two requests, and not by a request
//!     and a shared prefix group at once;
//!   * free + live + shared + trash == total (shared prefix pages counted
//!     once however many requests reference them);
//!   * table length never exceeds page capacity;
//!   * failed allocations have no side effects;
//!   * shared groups free their pages exactly when the last reference
//!     drops, never sooner.

use tetri_infer::kvcache::PagedKvCache;
use tetri_infer::util::Pcg;

#[derive(Debug)]
enum Op {
    Alloc { id: u64, tokens: u32 },
    Append { id: u64 },
    Release { id: u64 },
    SwapOut { id: u64 },
    ShareAlloc { key: u64, tokens: u32 },
    ShareRetain { key: u64 },
    ShareRelease { key: u64 },
}

fn random_op(rng: &mut Pcg, live: &[u64], shared: &[u64], next_id: &mut u64) -> Op {
    let roll = rng.f64();
    if roll < 0.15 {
        // shared prefix traffic: a small hot key space so retains and
        // last-reference frees both happen often
        let key = rng.range(1, 8);
        let sub = rng.f64();
        return if !shared.contains(&key) && sub < 0.5 {
            Op::ShareAlloc { key, tokens: rng.range(1, 200) as u32 }
        } else if sub < 0.8 {
            Op::ShareRetain { key }
        } else {
            Op::ShareRelease { key }
        };
    }
    if live.is_empty() || roll < 0.4 {
        let id = *next_id;
        *next_id += 1;
        Op::Alloc { id, tokens: rng.range(1, 400) as u32 }
    } else {
        let id = live[rng.index(live.len())];
        if roll < 0.8 {
            Op::Append { id }
        } else if roll < 0.92 {
            Op::Release { id }
        } else {
            Op::SwapOut { id }
        }
    }
}

fn run_case(seed: u64, ops: usize) {
    let mut rng = Pcg::new(seed);
    let total_pages = rng.range(4, 512) as u32;
    let page_size = [1u32, 4, 8, 16, 64][rng.index(5)];
    let mut kv = PagedKvCache::new(total_pages, page_size);
    let mut live: Vec<u64> = vec![];
    let mut shared: Vec<u64> = vec![];
    let mut refs: std::collections::HashMap<u64, u32> = Default::default();
    let mut next_id = 0u64;
    let mut expected_len: std::collections::HashMap<u64, u32> = Default::default();

    for step in 0..ops {
        let op = random_op(&mut rng, &live, &shared, &mut next_id);
        let ctx = || format!("seed={seed} step={step} op={op:?} pages={total_pages} psz={page_size}");
        match op {
            Op::Alloc { id, tokens } => {
                let free_before = kv.free_pages();
                match kv.alloc(id, tokens) {
                    Ok(()) => {
                        live.push(id);
                        expected_len.insert(id, tokens);
                        assert_eq!(kv.table(id).unwrap().len, tokens, "{}", ctx());
                    }
                    Err(_) => {
                        assert_eq!(kv.free_pages(), free_before, "failed alloc leaked: {}", ctx());
                        assert!(!kv.contains(id), "{}", ctx());
                    }
                }
            }
            Op::Append { id } => match kv.append_token(id) {
                Ok(()) => {
                    *expected_len.get_mut(&id).unwrap() += 1;
                }
                Err(_) => {
                    assert_eq!(kv.free_pages(), 0, "append may only fail when out of pages: {}", ctx());
                }
            },
            Op::Release { id } => {
                kv.release(id);
                live.retain(|&x| x != id);
                expected_len.remove(&id);
                assert!(!kv.contains(id), "{}", ctx());
            }
            Op::SwapOut { id } => {
                let want = expected_len.remove(&id);
                let got = kv.swap_out(id);
                assert_eq!(got, want, "{}", ctx());
                live.retain(|&x| x != id);
            }
            Op::ShareAlloc { key, tokens } => {
                let free_before = kv.free_pages();
                let shared_before = kv.shared_pages();
                match kv.alloc_shared(key, tokens) {
                    Ok(()) => {
                        shared.push(key);
                        refs.insert(key, 1);
                        assert_eq!(kv.shared_refs(key), 1, "{}", ctx());
                        assert_eq!(
                            kv.free_pages() + kv.shared_pages(),
                            free_before + shared_before,
                            "shared alloc must only move pages, not create them: {}",
                            ctx()
                        );
                    }
                    Err(_) => {
                        assert_eq!(kv.free_pages(), free_before, "failed shared alloc leaked: {}", ctx());
                        assert_eq!(kv.shared_refs(key), 0, "{}", ctx());
                    }
                }
            }
            Op::ShareRetain { key } => {
                let pages_before = kv.shared_pages();
                let known = kv.retain_shared(key);
                assert_eq!(known, refs.contains_key(&key), "{}", ctx());
                if known {
                    *refs.get_mut(&key).unwrap() += 1;
                }
                assert_eq!(kv.shared_pages(), pages_before, "retain must never cost pages: {}", ctx());
            }
            Op::ShareRelease { key } => {
                let freed = kv.release_shared(key);
                match refs.get_mut(&key) {
                    Some(r) if *r > 1 => {
                        *r -= 1;
                        assert_eq!(freed, 0, "pages freed while sharers remain: {}", ctx());
                    }
                    Some(_) => {
                        refs.remove(&key);
                        shared.retain(|&k| k != key);
                        assert!(freed > 0, "last release must free the run: {}", ctx());
                        assert_eq!(kv.shared_refs(key), 0, "{}", ctx());
                    }
                    None => assert_eq!(freed, 0, "unknown key must be inert: {}", ctx()),
                }
            }
        }
        for (&key, &r) in &refs {
            assert_eq!(kv.shared_refs(key), r, "refcount drift: {}", ctx());
        }
        kv.check_invariants().unwrap_or_else(|e| panic!("{e} [{}]", ctx()));
        for (&id, &len) in &expected_len {
            assert_eq!(kv.table(id).map(|t| t.len), Some(len), "length drift: {}", ctx());
        }
    }
}

#[test]
fn kv_invariants_hold_over_random_op_sequences() {
    for seed in 0..40 {
        run_case(seed, 400);
    }
}

#[test]
fn kv_invariants_hold_under_page_exhaustion() {
    // Tiny pools: almost every op contends for the last pages.
    for seed in 100..130 {
        let mut rng = Pcg::new(seed);
        let mut kv = PagedKvCache::new(3, 2);
        let mut ids = vec![];
        for step in 0..200 {
            if rng.f64() < 0.5 {
                let id = step as u64;
                if kv.alloc(id, rng.range(1, 6) as u32).is_ok() {
                    ids.push(id);
                }
            } else if let Some(&id) = ids.last() {
                if rng.f64() < 0.5 {
                    let _ = kv.append_token(id);
                } else {
                    kv.release(id);
                    ids.pop();
                }
            }
            kv.check_invariants().unwrap_or_else(|e| panic!("{e} seed={seed} step={step}"));
        }
    }
}

#[test]
fn kv_free_tokens_is_monotone_in_releases() {
    let mut kv = PagedKvCache::new(64, 8);
    let mut frees = vec![kv.free_tokens()];
    for id in 0..10u64 {
        kv.alloc(id, 37).unwrap();
        frees.push(kv.free_tokens());
    }
    for w in frees.windows(2) {
        assert!(w[1] < w[0]);
    }
    for id in 0..10u64 {
        let before = kv.free_tokens();
        kv.release(id);
        assert!(kv.free_tokens() > before);
    }
    assert_eq!(kv.free_pages(), 63);
}
