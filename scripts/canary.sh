#!/usr/bin/env bash
# Zero-alloc steady-state canary (DESIGN.md §Performance): build with the
# alloc-count counting allocator, run the 100k-request scale run through
# the streaming/macro-stepped hot path, and require
#   (a) zero steady-state heap allocations — with ALLOC_COUNT_STRICT=1
#       the tetri binary exits nonzero on any (the default here), and
#   (b) the wall budget (120s — loose on purpose: this catches
#       order-of-magnitude regressions, scripts/bench.sh records the
#       real numbers).
# Knobs: ALLOC_COUNT_STRICT=0 reports the count without failing;
# CANARY_REQUESTS / CANARY_BUDGET_S resize the run.
set -euo pipefail
cd "$(dirname "$0")/../rust"
requests="${CANARY_REQUESTS:-100000}"
budget="${CANARY_BUDGET_S:-120}"
strict="${ALLOC_COUNT_STRICT:-1}"
cargo build --release --features alloc-count --bin tetri
start=$(date +%s)
ALLOC_COUNT_STRICT="${strict}" cargo run --release --features alloc-count --quiet --bin tetri -- \
  sim --spec ../scenarios/scale.json --requests "${requests}" --no-records --no-baseline
elapsed=$(( $(date +%s) - start ))
echo "alloc-count canary: ${requests}-request scale run in ${elapsed}s (strict=${strict})"
if [ "${elapsed}" -gt "${budget}" ]; then
  echo "alloc-count canary FAILED: took ${elapsed}s (budget ${budget}s)" >&2
  exit 1
fi
