#!/usr/bin/env bash
# Regenerate the machine-readable perf baselines at the repo root:
#   BENCH_sched.json   — L3 microbenches (benches/scheduler.rs)
#   BENCH_cluster.json — end-to-end DES throughput (benches/cluster.rs)
# Run after any hot-path change and commit the refreshed files; future
# PRs regress against them (EXPERIMENTS.md §Perf).
set -euo pipefail
cd "$(dirname "$0")/../rust"
cargo bench --bench scheduler
cargo bench --bench cluster
cd ..
echo "perf baselines:"
ls -l BENCH_sched.json BENCH_cluster.json
