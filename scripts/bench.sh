#!/usr/bin/env bash
# Regenerate the machine-readable perf baselines at the repo root:
#   BENCH_sched.json   — L3 microbenches (benches/scheduler.rs)
#   BENCH_cluster.json — end-to-end DES throughput (benches/cluster.rs)
#                        plus the "engine" section (benches/engine.rs):
#                        old-vs-new queue events/sec and the 1M-request
#                        scale run's events/sec + peak arena size
# Run after any hot-path change and commit the refreshed files; future
# PRs regress against them (EXPERIMENTS.md §Perf). The engine bench runs
# last: it merges into the BENCH_cluster.json the cluster bench wrote.
# (Set ENGINE_BENCH_REQUESTS to shrink the 1M scale run while iterating.)
set -euo pipefail
cd "$(dirname "$0")/../rust"
# Provenance: the bench binaries stamp each section with git SHA + wall
# timestamp (util::bench_meta; BENCH_GIT_SHA overrides when git is
# unavailable). Echo it here too so the terminal log is self-describing.
echo "bench provenance: $(git rev-parse --short HEAD 2>/dev/null || echo unknown) at $(date -u +%Y-%m-%dT%H:%M:%SZ)"
cargo bench --bench scheduler
cargo bench --bench cluster
cargo bench --bench engine
# The optimizer bench merges the "optimizer" section (search cells/sec +
# fraction-of-exhaustive) and hard-asserts the < 0.5 work bound.
cargo bench --bench optimizer
cd ..
echo "perf baselines:"
ls -l BENCH_sched.json BENCH_cluster.json
