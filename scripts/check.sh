#!/usr/bin/env bash
# Tier-1 verify gate (ROADMAP.md): build + full test suite from rust/.
# Every PR runs this before landing:  ./scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/../rust"
cargo build --release
cargo test -q
echo "tier-1 verify: OK"
