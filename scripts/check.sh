#!/usr/bin/env bash
# Tier-1 verify gate (ROADMAP.md): build + full test suite from rust/,
# plus (a) every example builds and (b) every shipped scenario spec still
# loads and runs end-to-end in smoke mode (capped request counts), so
# scenarios/ can never rot. Every PR runs this before landing:
#   ./scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/../rust"
cargo build --release
cargo build --release --examples
cargo test -q

# Smoke-run every spec through the CLI: --requests caps flat scenarios
# and each phase of phased ones, so this stays fast while exercising the
# full spec → scenario → driver → report pipeline.
for spec in ../scenarios/*.json; do
  echo "spec smoke: ${spec}"
  cargo run --release --quiet --bin tetri -- sim --spec "${spec}" --requests 8 >/dev/null
done

echo "tier-1 verify: OK"
