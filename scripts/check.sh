#!/usr/bin/env bash
# Tier-1 verify gate (ROADMAP.md): build + full test suite from rust/,
# plus (a) every example builds, (b) lints are clean (clippy -D warnings,
# rustfmt --check), and (c) every shipped scenario spec still loads and
# runs end-to-end in smoke mode (capped request counts), so scenarios/
# can never rot. The instance-engine specs (scenarios/elastic.json,
# scenarios/hybrid.json) ride the same glob as every other spec. Every PR
# runs this before landing:
#   ./scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/../rust"
cargo build --release
cargo build --release --examples
cargo test -q

# Lint gate: warnings are errors, formatting is canonical. (Warn-and-skip
# on toolchains that ship without the components.)
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "WARN: clippy not installed; lint gate skipped" >&2
fi
if cargo fmt --version >/dev/null 2>&1; then
  cargo fmt --check
else
  echo "WARN: rustfmt not installed; format gate skipped" >&2
fi

# Smoke-run every spec through the CLI: --requests caps flat scenarios
# and each phase of phased ones, so this stays fast while exercising the
# full spec → scenario → driver → report pipeline (including the elastic,
# hybrid, and SLO paths). Drift guard: the floor pins the shipped set's
# minimum size, so a deleted spec (or an empty/mis-globbed directory —
# set -e already aborts on the unmatched-glob cargo failure) fails the
# gate instead of rotting unsmoked.
specs_run=0
for spec in ../scenarios/*.json; do
  echo "spec smoke: ${spec}"
  cargo run --release --quiet --bin tetri -- sim --spec "${spec}" --requests 8 >/dev/null
  specs_run=$((specs_run + 1))
done
if [ "${specs_run}" -lt 24 ]; then
  echo "spec drift guard FAILED: smoke-ran only ${specs_run} scenarios/*.json (floor 24)" >&2
  exit 1
fi

# The SLO specs must run under every driver (the apples-to-apples
# goodput comparison: same trace, same gate logic; queue-depth sheds
# track each system's own congestion by design): smoke tetri/vllm/hybrid
# on both, and require the mixed + overload specs to exist by name.
for spec in ../scenarios/slo_mixed.json ../scenarios/slo_overload.json; do
  test -f "${spec}" || { echo "missing shipped SLO spec ${spec}" >&2; exit 1; }
  for drv in tetri vllm hybrid; do
    echo "slo smoke: ${spec} under ${drv}"
    cargo run --release --quiet --bin tetri -- sim --spec "${spec}" --driver "${drv}" \
      --requests 8 --no-baseline >/dev/null
  done
done

# Fault-injection matrix: every chaos spec must run under every driver
# (the same deterministic chaos schedule fires against the disaggregated
# cluster, the coupled baseline, and the hybrid fleet), and each run's
# conservation law is re-checked end-to-end by the suite above; here we
# smoke the full CLI path including the --fault flag spelling.
for spec in ../scenarios/chaos_crash.json ../scenarios/chaos_link.json ../scenarios/chaos_storm.json; do
  test -f "${spec}" || { echo "missing shipped chaos spec ${spec}" >&2; exit 1; }
  for drv in tetri vllm hybrid; do
    echo "chaos smoke: ${spec} under ${drv}"
    cargo run --release --quiet --bin tetri -- sim --spec "${spec}" --driver "${drv}" \
      --requests 24 --no-baseline >/dev/null
  done
done
# Prefix-cache matrix: the reuse specs must run under every driver (the
# stamps are pure metadata on vllm — the baseline ignores them — while
# tetri/hybrid consume them through the radix cache), and the CLI --prefix
# flag spelling gets one smoke of its own.
for spec in ../scenarios/prefix_reuse.json ../scenarios/multiturn.json; do
  test -f "${spec}" || { echo "missing shipped prefix spec ${spec}" >&2; exit 1; }
  for drv in tetri vllm hybrid; do
    echo "prefix smoke: ${spec} under ${drv}"
    cargo run --release --quiet --bin tetri -- sim --spec "${spec}" --driver "${drv}" \
      --requests 24 --no-baseline >/dev/null
  done
done
echo "prefix smoke: CLI --prefix flag"
cargo run --release --quiet --bin tetri -- sim --workload HPLD --requests 24 --rate 24 \
  --prefill 2 --decode 2 --prefix n_prefixes=8,prefix_len=512,zipf=1.0 \
  --no-baseline >/dev/null

# Telemetry smoke: --trace must produce a loadable Chrome trace-event
# JSON on the overload and chaos specs under every driver (the span
# machine covers the disaggregated, coupled, and hybrid pipelines). The
# full schema pin lives in tests/telemetry.rs (real parser round trip);
# this tiny check guards the CLI path end to end: the file exists, is
# one JSON object with a traceEvents array, and contains complete spans.
telemetry_tmp=$(mktemp -d)
trap 'rm -rf "${telemetry_tmp}"' EXIT
for spec in ../scenarios/slo_overload.json ../scenarios/chaos_crash.json; do
  for drv in tetri vllm hybrid; do
    echo "telemetry smoke: ${spec} under ${drv} (--trace)"
    out="${telemetry_tmp}/$(basename "${spec}" .json).${drv}.trace.json"
    cargo run --release --quiet --bin tetri -- sim --spec "${spec}" --driver "${drv}" \
      --requests 24 --no-baseline --telemetry sample_ms=10 --trace "${out}" \
      --series "${telemetry_tmp}/series.csv" >/dev/null
    test -s "${out}" || { echo "telemetry smoke FAILED: ${out} missing/empty" >&2; exit 1; }
    for needle in '"displayTimeUnit":"ms"' '"traceEvents":[' '"ph":"X"' '"process_name"'; do
      grep -qF "${needle}" "${out}" || {
        echo "telemetry smoke FAILED: ${out} lacks ${needle}" >&2; exit 1; }
    done
    head -c 1 "${out}" | grep -qF '{' || {
      echo "telemetry smoke FAILED: ${out} is not a JSON object" >&2; exit 1; }
    head -n 1 "${telemetry_tmp}/series.csv" | grep -qF 't_ms,in_flight,queue' || {
      echo "telemetry smoke FAILED: series CSV header drifted" >&2; exit 1; }
  done
done

# Optimizer smoke: the topology search CLI must run the shipped search
# spec end to end (short horizon, 2 workers) and emit a frontier +
# recommendation deterministically — the full pins live in
# tests/golden.rs and tests/optimizer.rs; this guards the CLI spelling.
echo "optimizer smoke: sim optimize --spec scenarios/optimize_mixed.json"
cargo run --release --quiet --bin tetri -- sim optimize \
  --spec ../scenarios/optimize_mixed.json --requests 24 --workers 2 >/dev/null

echo "chaos smoke: CLI --fault flag"
cargo run --release --quiet --bin tetri -- sim --workload Mixed --requests 24 --rate 24 \
  --decode 2 --fault kind=restart,at_ms=100,instance=2,down_ms=250 \
  --fault kind=straggler,at_ms=50,down_ms=300,factor=2 --no-baseline >/dev/null

# Perf-regression canary: a timed 100k-request release-mode run through
# the streaming/macro-stepped hot path (records off, no baseline run).
# The budget is deliberately loose — it exists to catch order-of-magnitude
# regressions (an accidental O(n) queue op, records kept at scale), not
# to benchmark; scripts/bench.sh records the real numbers.
canary_start=$(date +%s)
cargo run --release --quiet --bin tetri -- sim --spec ../scenarios/scale.json \
  --requests 100000 --no-records --no-baseline >/dev/null
canary_elapsed=$(( $(date +%s) - canary_start ))
echo "perf canary: 100k-request scale run in ${canary_elapsed}s"
if [ "${canary_elapsed}" -gt 120 ]; then
  echo "perf canary FAILED: 100k-request run took ${canary_elapsed}s (budget 120s)" >&2
  exit 1
fi

# Zero-alloc steady-state canary, non-strict: rebuild with the
# alloc-count counting allocator and *report* the steady-window
# allocation count without failing on it — strict enforcement
# (ALLOC_COUNT_STRICT=1) is CI's dedicated canary step, so a host quirk
# can't block the local tier-1 gate (scripts/canary.sh; DESIGN.md
# §Performance).
ALLOC_COUNT_STRICT=0 CANARY_REQUESTS=50000 ../scripts/canary.sh

echo "tier-1 verify: OK"
