#!/usr/bin/env bash
# Bench-regression gate: re-run the engine bench at a reduced request
# count and compare its scale-run events/sec against the committed
# BENCH_cluster.json baseline, then the optimizer bench (reduced
# per-cell horizon) against the committed search cells/sec. The compares
# themselves live in benches/engine.rs and benches/optimizer.rs
# (tolerance band via BENCH_TOLERANCE, default 0.25).
# Warn-only by default — committed numbers from a different
# host/toolchain are not comparable; set BENCH_GATE_STRICT=1 once a
# baseline has been blessed on the CI host to turn a regression into a
# failure. The committed baseline file is restored afterwards so the gate
# never dirties the tree with reduced-size numbers.
set -euo pipefail
cd "$(dirname "$0")/.."
requests="${ENGINE_BENCH_REQUESTS:-200000}"
baseline=BENCH_cluster.json
backup=""
restore() {
  if [ -n "${backup}" ]; then
    mv -f "${backup}" "${baseline}"
  else
    rm -f "${baseline}"
  fi
}
trap restore EXIT
if [ -f "${baseline}" ]; then
  backup=$(mktemp)
  cp "${baseline}" "${backup}"
fi
( cd rust && ENGINE_BENCH_REQUESTS="${requests}" cargo bench --bench engine )
opt_requests="${OPTIMIZER_BENCH_REQUESTS:-96}"
( cd rust && OPTIMIZER_BENCH_REQUESTS="${opt_requests}" cargo bench --bench optimizer )
echo "bench gate: done (strict=${BENCH_GATE_STRICT:-0}, tolerance=${BENCH_TOLERANCE:-0.25})"
